"""Cross-job production coalescing + lock-striped cache tests (ISSUE-10).

Fast half (tier-1): ProductionTable single-flight protocol (one
producer per in-flight key, zero-copy hand-off, abort/retry, orphan
eviction), striped TieredCache serving equivalence with the single-lock
layout, request samplers, and the frequency admission doorkeeper.

The concurrent stress half lives in ``TestConcurrentStress`` (marked
``slow``/``stress``, run by the CI stress job): a hypothesis sweep
asserting the striped cache keeps exact byte ledgers and one-directional
ODS metadata consistency under racing admit/lookup/resize/evict
threads, and a many-thread single-flight hammer.
"""
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (AZURE_NC96, DatasetProfile, SenecaConfig,
                       SenecaServer, SenecaService)
from repro.api.policies import FrequencyAdmission, resolve_policy
from repro.api.server import CODE_FORM, FORM_CODE
from repro.api.telemetry import TelemetryAggregator
from repro.cache.coalesce import ProductionTable
from repro.cache.store import FORMS, TieredCache
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.workload import (JobSpec, PhaseShiftSampler, ZipfianSampler,
                            make_request_sampler)


# ----------------------------------------------------------------------
# single-flight protocol
class TestProductionTable:
    def test_k_threads_one_producer_identical_bytes(self):
        """The satellite's contract: K threads missing the same key run
        exactly one producer; every thread observes identical bytes and
        joiners receive the leader's array zero-copy."""
        table = ProductionTable()
        k = 8
        produced = []
        results = [None] * k
        barrier = threading.Barrier(k)
        lock = threading.Lock()

        def produce():
            with lock:
                produced.append(threading.get_ident())
            # widen the in-flight window so every other thread joins
            import time
            time.sleep(0.05)
            return np.arange(16, dtype=np.float32)

        def worker(i):
            barrier.wait()
            while True:
                leader, flight = table.begin(7, "augmented")
                if leader:
                    out = produce()
                    table.finish(flight, out)
                    results[i] = out
                    return
                ok, value = table.join(flight)
                if ok:
                    results[i] = value
                    return
                if not flight.done:
                    results[i] = produce()
                    return

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(k)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(produced) == 1, "duplicate productions of one key"
        leader_out = results[0]
        for r in results:
            assert r is leader_out, "joiner did not get zero-copy value"
        assert table.coalesced == k - 1
        assert table.duplicates == 0
        assert len(table) == 0

    def test_observe_mode_counts_duplicates(self):
        table = ProductionTable(enabled=False)
        leader, flight = table.begin(3, "augmented")
        assert leader and flight is not None
        again, none_flight = table.begin(3, "augmented")
        assert again and none_flight is None      # produce anyway
        assert table.duplicates == 1
        table.finish(flight, b"v")
        assert len(table) == 0

    def test_abort_wakes_joiner_who_retries_as_leader(self):
        table = ProductionTable()
        _leader, flight = table.begin(5, "augmented")
        got = {}

        def joiner():
            is_leader, fl = table.begin(5, "augmented")
            assert not is_leader
            ok, value = table.join(fl)
            got["join"] = (ok, value)
            assert fl.done               # aborted, not timed out
            is_leader, fl2 = table.begin(5, "augmented")
            got["retry_leads"] = is_leader
            table.finish(fl2, b"retried")

        t = threading.Thread(target=joiner)
        t.start()
        import time
        time.sleep(0.02)
        table.abort(flight, RuntimeError("boom"))
        t.join()
        assert got["join"] == (False, None)
        assert got["retry_leads"]
        assert flight.error is not None

    def test_abort_without_error_never_reads_as_success(self):
        table = ProductionTable()
        _leader, flight = table.begin(9, "augmented")
        table.abort(flight)
        ok, value = table.join(flight)
        assert (ok, value) == (False, None)

    def test_timeout_evicts_orphaned_flight(self):
        table = ProductionTable(timeout_s=0.02)
        _leader, flight = table.begin(1, "augmented")
        is_leader, fl = table.begin(1, "augmented")
        assert not is_leader
        ok, _ = table.join(fl)               # leader never finishes
        assert not ok
        assert len(table) == 0               # orphan evicted
        is_leader, fl2 = table.begin(1, "augmented")
        assert is_leader                     # fresh flight, no stall
        table.finish(fl2, b"v")
        # the original leader finishing late must not pop the successor
        table.finish(flight, b"stale")
        assert len(table) == 0

    def test_inflight_mask(self):
        table = ProductionTable()
        assert table.inflight_mask(8) is None
        _l1, f1 = table.begin(2, "augmented")
        _l2, f2 = table.begin(6, "augmented")
        mask = table.inflight_mask(8)
        assert mask is not None
        assert list(np.flatnonzero(mask)) == [2, 6]
        table.finish(f1, b"a")
        table.abort(f2)
        assert table.inflight_mask(8) is None

    def test_deterministic_clock_without_ticket_declines(self):
        class FakeClock:
            deterministic = True

            def now(self):
                return 0.0

            def bound_ticket(self):
                return None

        table = ProductionTable()
        _leader, flight = table.begin(4, "augmented")
        is_leader, fl = table.begin(4, "augmented")
        assert not is_leader
        ok, value = table.join(fl, FakeClock())
        assert (ok, value) == (False, None)
        assert table.duplicates == 1
        assert not fl.done                   # caller produces itself
        table.finish(flight, b"v")

    def test_telemetry_coalesce_counters(self):
        tel = TelemetryAggregator()
        assert "coalesced" not in tel.as_dict()      # additive shape
        tel.record_coalesced(0.25)
        tel.record_coalesced(0.75)
        out = tel.as_dict()
        assert out["coalesced"] == 2
        assert out["coalesce_wait_s"] == pytest.approx(1.0)


# ----------------------------------------------------------------------
# lock-striped cache
class TestStripedCache:
    def _fill(self, cache, n=48):
        for k in range(n):
            form = FORMS[k % 3]
            cache.insert(k, form, b"x" * (100 + k), 100 + k)

    def test_striped_matches_single_lock_serving(self):
        flat = TieredCache(60_000, (0.4, 0.3, 0.3))
        striped = TieredCache(60_000, (0.4, 0.3, 0.3), n_stripes=4)
        self._fill(flat)
        self._fill(striped)
        for k in range(64):
            assert flat.form_of(k) == striped.form_of(k)
            f_form, f_val, f_tier = flat.lookup_tiered(k)
            s_form, s_val, s_tier = striped.lookup_tiered(k)
            assert (f_form, f_tier) == (s_form, s_tier)
            assert f_val == s_val
        assert flat.lookup_misses == striped.lookup_misses
        assert flat.bytes_used() == striped.bytes_used()
        for form in FORMS:
            keys = list(range(64))
            assert list(flat.contains_many(form, keys)) \
                == list(striped.contains_many(form, keys))
            assert len(flat.parts[form]) == len(striped.parts[form])

    def test_striped_ledgers_exact_and_resize(self):
        cache = TieredCache(60_000, (0.4, 0.3, 0.3), n_stripes=4)
        self._fill(cache)
        for stripe in cache._stripes:
            for form, part in stripe.items():
                assert part.stats.bytes_used == sum(part._sizes.values())
                assert set(part._data) == set(part._sizes)
                assert part.stats.bytes_used <= part.capacity
        cache.resize((0.2, 0.3, 0.5))
        total = 0
        for stripe in cache._stripes:
            for part in stripe.values():
                assert part.stats.bytes_used == sum(part._sizes.values())
                assert part.stats.bytes_used <= part.capacity
                total += part.capacity
        assert total <= cache.capacity
        # whole-cache lock: ascending acquire over every stripe
        with cache.lock:
            pass
        cache.close()

    def test_server_integration_striped_and_coalescing(self):
        ds = tiny(n=96)
        server = SenecaServer.for_dataset(ds, cache_frac=0.5, seed=0,
                                          lock_stripes=4, coalesce=True)
        storage = RemoteStorage(ds)
        pipe = DSIPipeline(server.open_session(batch_size=8), storage,
                           n_workers=2, seed=0)
        for _ in range(6):
            batch = pipe.next_batch()
            assert batch["images"].shape[0] == 8
        stats = server.service.stats()
        assert stats["production"]["led"] > 0
        assert stats["production"]["enabled"]
        pipe.stop()
        server.close()


# ----------------------------------------------------------------------
# request samplers
class TestRequestSamplers:
    def test_zipfian_distinct_deterministic_and_skewed(self):
        a = ZipfianSampler(256, 32, seed=1)
        b = ZipfianSampler(256, 32, seed=1)
        counts = np.zeros(256, np.int64)
        for _ in range(40):
            ra, rb = a.next_request(), b.next_request()
            assert np.array_equal(ra, rb)        # same seed, same stream
            assert len(set(ra.tolist())) == len(ra)
            assert ra.min() >= 0 and ra.max() < 256
            counts[ra] += 1
        hot = a._ranks[:32]
        cold = a._ranks[-32:]
        assert counts[hot].sum() > counts[cold].sum()

    def test_zipfian_state_roundtrip(self):
        a = ZipfianSampler(128, 16, seed=7)
        for _ in range(5):
            a.next_request()
        snap = a.state_dict()
        expect = [a.next_request() for _ in range(3)]
        b = ZipfianSampler(128, 16, seed=99)
        b.load_state_dict(snap)
        got = [b.next_request() for _ in range(3)]
        for e, g in zip(expect, got):
            assert np.array_equal(e, g)
        with pytest.raises(ValueError):
            ZipfianSampler(64, 16, seed=0).load_state_dict(snap)

    def test_phase_shift_slides_window(self):
        s = PhaseShiftSampler(256, 16, seed=3, window_frac=0.25,
                              period=4, shift_frac=0.5)
        first_phase = np.concatenate([s.next_request() for _ in range(4)])
        assert first_phase.max() < s.window      # offset 0 phase
        s.next_request()
        assert s._offset == s.shift              # window advanced
        snap = s.state_dict()
        expect = [s.next_request() for _ in range(3)]
        r = PhaseShiftSampler(256, 16, seed=8, window_frac=0.25,
                              period=4, shift_frac=0.5)
        r.load_state_dict(snap)
        for e in expect:
            assert np.array_equal(e, r.next_request())

    def test_factory_and_jobspec_validation(self):
        s = make_request_sampler("zipfian", 64, 8, seed=0)
        assert isinstance(s, ZipfianSampler)
        assert make_request_sampler(None, 64, 8, seed=0).n == 64
        with pytest.raises(ValueError, match="unknown request sampler"):
            make_request_sampler("nope", 64, 8, seed=0)
        spec = JobSpec(name="j", batch_size=4, sampler="phase-shift")
        assert spec.sampler == "phase-shift"
        with pytest.raises(ValueError):
            JobSpec(name="j", batch_size=4, sampler="bogus")


# ----------------------------------------------------------------------
# frequency admission
class TestFrequencyAdmission:
    def test_doorkeeper_threshold(self):
        adm = FrequencyAdmission(threshold=2)
        assert not adm.wants(None, 11, "augmented")   # first touch
        assert adm.wants(None, 11, "augmented")       # second passes
        assert adm.wants(None, 11, "augmented")
        assert not adm.wants(None, 12, "encoded")     # independent key

    def test_aging_decays_counts(self):
        adm = FrequencyAdmission(threshold=2, window=4)
        for _ in range(4):
            adm.wants(None, 5, "augmented")           # 4th obs triggers age
        # count was 4, halved to 2 by the aging pass: still admitted
        assert adm.wants(None, 5, "augmented")
        adm2 = FrequencyAdmission(threshold=3, window=2)
        adm2.wants(None, 9, "augmented")
        adm2.wants(None, 9, "augmented")              # ages: 2 -> 1
        assert not adm2.wants(None, 9, "augmented")   # 1+1 < 3

    def test_registry_resolution(self):
        adm = resolve_policy("admission", "frequency")
        assert isinstance(adm, FrequencyAdmission)

    def test_service_runs_with_frequency_admission(self):
        profile = DatasetProfile("freq", 64, 1_000, decoded_bytes=1_500,
                                 augmented_bytes=2_000)
        svc = SenecaService(SenecaConfig(
            cache_bytes=64_000, hardware=AZURE_NC96, dataset=profile,
            split=(0.4, 0.3, 0.3), seed=0, admission="frequency"))
        svc.register_job(0, 4)
        assert not svc.admit(1, "augmented", b"x" * 100, 100)  # 1st touch
        assert svc.admit(1, "augmented", b"x" * 100, 100)      # doorkeeper
        assert svc.cache.form_of(1) == "augmented"
        svc.close()


# ----------------------------------------------------------------------
# stress half: racing threads (CI stress job; excluded from tier-1)
N_KEYS = 64
OPS = ("admit_encoded", "admit_decoded", "admit_augmented", "lookup",
       "evict_augmented", "resize")
op_strategy = st.lists(
    st.tuples(st.sampled_from(OPS),
              st.integers(0, N_KEYS - 1),
              st.integers(1, 1_500),
              st.floats(0.05, 0.9),
              st.floats(0.05, 0.9)),
    min_size=8, max_size=80)


def _striped_service() -> SenecaService:
    profile = DatasetProfile("stripe-prop", N_KEYS, 1_000,
                             decoded_bytes=1_500, augmented_bytes=2_000)
    # "on-change" keeps the repartition controller active, which is what
    # arms admit()'s deferred-mark re-validation — resizing live against
    # concurrent admits is only supported with an active controller
    return SenecaService(SenecaConfig(
        cache_bytes=16_384, hardware=AZURE_NC96, dataset=profile,
        split=(0.4, 0.3, 0.3), seed=3, lock_stripes=4,
        repartition="on-change"))


@pytest.mark.slow
@pytest.mark.stress
@settings(max_examples=15, deadline=None)
@given(ops=op_strategy)
def test_striped_ledgers_and_ods_consistency_under_races(ops):
    """4 threads race the drawn op tape against a 4-stripe service.
    Threads own disjoint key residues for mutations (the service
    serializes same-key admits anyway; disjoint ownership keeps the
    *oracle* race-free) but share every stripe and issue lookups on
    all keys; thread 0 additionally resizes whole-cache.  At join:
    exact byte ledgers per stripe partition, capacities respected,
    and the one-directional ODS contract — a nonzero status must
    name a resident form."""
    svc = _striped_service()
    n_threads = 4
    errors = []

    def run(t):
        try:
            for kind, key, nbytes, f_enc, f_rest in ops:
                if kind == "lookup":
                    svc.lookup((key + t) % N_KEYS)
                    continue
                if kind == "resize":
                    if t == 0:
                        from repro.core import mdp
                        x_e = round(f_enc, 3)
                        x_d = round((1.0 - x_e) * f_rest, 3)
                        svc.apply_partition(mdp.Partition(
                            x_e, x_d, round(1.0 - x_e - x_d, 3),
                            throughput=float("nan")))
                    continue
                key = (key - key % n_threads) + t   # own residue only
                key %= N_KEYS
                if kind == "evict_augmented":
                    status = svc.backend.status_of(np.asarray([key]))
                    if int(status[0]) == FORM_CODE["augmented"]:
                        svc.cache.evict(key, "augmented")
                        svc.backend.mark_evicted(np.asarray([key]))
                else:
                    form = kind[len("admit_"):]
                    svc.admit(key, form, b"x" * nbytes, nbytes)
        except Exception as e:          # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=run, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    svc.reconcile_evictions()
    cache = svc.cache
    with cache.lock:
        total_cap = 0
        for stripe in cache._stripes:
            for form, part in stripe.items():
                assert part.stats.bytes_used == \
                    sum(part._sizes.values()), \
                    f"{form}: byte ledger out of sync under races"
                assert set(part._data) == set(part._sizes)
                assert part.stats.bytes_used <= part.capacity
                total_cap += part.capacity
        assert total_cap <= cache.capacity
        status = svc.backend.status_of(np.arange(N_KEYS))
        for key in np.flatnonzero(status):
            form = CODE_FORM[int(status[key])]
            assert int(key) in cache.parts[form], \
                f"status claims {form} for {key} but cache lost it"
    svc.close()


@pytest.mark.slow
@pytest.mark.stress
def test_single_flight_hammer():
    """16 threads x 30 rounds on one key: every round runs exactly
    one producer and hands identical bytes to all."""
    import time
    for rnd in range(30):
        table = ProductionTable()
        k = 16
        produced = []
        results = [None] * k
        barrier = threading.Barrier(k)
        lock = threading.Lock()

        def worker(i, rnd=rnd, table=table, produced=produced,
                   results=results, barrier=barrier, lock=lock):
            barrier.wait()
            while True:
                leader, flight = table.begin(rnd, "augmented")
                if leader:
                    with lock:
                        produced.append(i)
                    time.sleep(0.005)
                    out = np.full(8, rnd, np.int32)
                    table.finish(flight, out)
                    results[i] = out
                    return
                ok, value = table.join(flight)
                if ok:
                    results[i] = value
                    return
                if not flight.done:
                    results[i] = np.full(8, rnd, np.int32)
                    return

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(k)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(produced) == 1
        for r in results:
            assert np.array_equal(r, np.full(8, rnd, np.int32))
        assert len(table) == 0
