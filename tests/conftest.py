import os
import sys

# keep smoke tests on 1 device — ONLY the dry-run forces 512 fake devices
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ----------------------------------------------------------------------
# hypothesis fallback: the container may not ship hypothesis (the seed's
# property-test modules then fail at *collection*).  Install a minimal
# deterministic stand-in covering the handful of strategies these tests
# use, so the properties still run (with seeded random examples) when the
# real library is absent.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def _integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _floats(lo, hi):
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elem.sample(rng)
            for _ in range(rng.randint(min_size, max_size))])

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.sample(rng) for e in elems))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))

    def _given(**strategies):
        def deco(fn):
            def wrapper():
                rng = random.Random(0)
                n = getattr(wrapper, "_max_examples", 10)
                for _ in range(n):
                    fn(**{k: s.sample(rng)
                          for k, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 10)
            return wrapper
        return deco

    def _settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.lists = _lists
    _st.tuples = _tuples
    _st.sampled_from = _sampled_from
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
