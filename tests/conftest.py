import os
import sys

# keep smoke tests on 1 device — ONLY the dry-run forces 512 fake devices
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
