"""Property sweeps for the consistent-hash ShardRouter.

Two properties over random (shard-count, seed, key-population) draws:

* **balance** — contiguous sample-id populations spread across shards
  with a bounded max/min load ratio (64 virtual nodes per shard keep
  the ring segments small relative to any shard's share);
* **minimal remapping** — growing N -> N+1 moves keys *only* onto the
  new shard (ring points depend only on (seed, shard, vnode), so old
  segments are untouched except where a new point splits them), and
  shrinking N+1 -> N moves only the keys the removed shard owned.

Strategies stick to the subset the conftest hypothesis fallback shim
implements (integers/floats/lists/tuples/sampled_from).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.service.router import ShardRouter

# the balance/remap sweeps are tier-1's slow half: deselected by
# pytest.ini, run by the CI stress job
pytestmark = pytest.mark.slow


@settings(max_examples=30)
@given(n_shards=st.integers(2, 8), seed=st.integers(0, 10_000),
       n_keys=st.integers(2_000, 6_000))
def test_router_load_stays_balanced(n_shards, seed, n_keys):
    r = ShardRouter(n_shards, vnodes=64, seed=seed)
    loads = r.load(np.arange(n_keys, dtype=np.int64))
    assert loads.sum() == n_keys
    assert (loads > 0).all(), loads
    # 64 vnodes/shard: worst observed skew is well under 2x; 3x is the
    # regression alarm, not the expectation
    assert loads.max() / loads.min() < 3.0, loads


@settings(max_examples=30)
@given(n_shards=st.integers(1, 8), seed=st.integers(0, 10_000))
def test_router_grow_remaps_minimally(n_shards, seed):
    keys = np.arange(4_000, dtype=np.int64)
    small = ShardRouter(n_shards, vnodes=64, seed=seed)
    large = ShardRouter(n_shards + 1, vnodes=64, seed=seed)
    before = small.shard_of_many(keys)
    after = large.shard_of_many(keys)
    moved = before != after
    # every moved key lands on the new shard, nothing reshuffles among
    # the survivors
    assert (after[moved] == n_shards).all()
    # and the moved share stays near the ideal 1/(N+1)
    frac = moved.sum() / len(keys)
    assert 0.0 < frac <= min(1.0, 2.5 / (n_shards + 1)), frac


@settings(max_examples=30)
@given(n_shards=st.integers(1, 8), seed=st.integers(0, 10_000))
def test_router_shrink_remaps_minimally(n_shards, seed):
    keys = np.arange(4_000, dtype=np.int64)
    large = ShardRouter(n_shards + 1, vnodes=64, seed=seed)
    small = ShardRouter(n_shards, vnodes=64, seed=seed)
    before = large.shard_of_many(keys)
    after = small.shard_of_many(keys)
    moved = before != after
    # only keys the removed shard owned change owners
    assert (before[moved] == n_shards).all()
    assert (after[~moved] == before[~moved]).all()
