"""Simulator-level reproduction checks (paper-claim scale tests live in
benchmarks/; these are fast sanity versions)."""
import numpy as np
import pytest

from repro.core import mdp
from repro.core.perf_model import (AZURE_NC96, GB, DatasetProfile,
                                   JobProfile, dsi_throughput)
from repro.sim.desim import (ALL_LOADERS, DSISimulator, LoaderSpec,
                             MDP_ONLY, MINIO, PYTORCH, QUIVER, SENECA,
                             SimJob)

DS = DatasetProfile("openimages-tiny", 60_000, 315.84e3)


def _run(spec, jobs=2, epochs=2, cache=12 * GB, seed=0, **kw):
    sim = DSISimulator(AZURE_NC96, DS, spec, cache_bytes=cache, seed=seed)
    return sim.run([SimJob(j, gpu_rate=3500, batch_size=512, epochs=epochs)
                    for j in range(jobs)]), sim


def test_seneca_beats_all_baselines():
    results = {s.name: _run(s)[0].throughput
               for s in (PYTORCH, MINIO, QUIVER, SENECA)}
    assert results["seneca"] >= results["minio"], results
    assert results["seneca"] >= results["pytorch"], results
    assert results["seneca"] >= results["quiver"] * 0.95, results


def test_seneca_makespan_reduction_vs_pytorch():
    """Fig. 10 direction: concurrent-job makespan drops substantially."""
    r_pt, _ = _run(PYTORCH)
    r_se, _ = _run(SENECA)
    reduction = 1 - r_se.makespan / r_pt.makespan
    assert reduction > 0.25, reduction


def test_mdp_only_beats_static_encoded():
    r_minio, _ = _run(MINIO)
    r_mdp, _ = _run(MDP_ONLY)
    assert r_mdp.throughput >= r_minio.throughput


def test_epoch_times_monotone_warmup():
    """First (cold) epoch is slower than stable epochs (Fig. 15 lines)."""
    r, _ = _run(SENECA, epochs=3)
    for j in r.first_epoch_s:
        assert r.first_epoch_s[j] >= 0.8 * r.stable_epoch_s[j]


def test_model_sim_correlation_quick():
    """Fig. 8 in miniature: closed-form model vs simulator across splits
    correlates strongly (full sweep in benchmarks/fig8_validation)."""
    splits = [(1.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, 1.0),
              (0.5, 0.5, 0.0), (0.0, 0.5, 0.5)]
    model_v, sim_v = [], []
    for sp in splits:
        spec = LoaderSpec(f"fixed{sp}", split_override=sp,
                          cache_forms=("encoded", "decoded", "augmented"),
                          sampling="random", evict_refcount=False)
        r, _ = _run(spec, jobs=1, epochs=2)
        sim_v.append(r.throughput)
        model_v.append(float(dsi_throughput(
            AZURE_NC96, DatasetProfile(DS.name, DS.n_total, DS.s_data),
            JobProfile(), *sp).overall))
    corr = np.corrcoef(model_v, sim_v)[0, 1]
    assert corr > 0.8, (corr, model_v, sim_v)


def test_preprocess_sharing_reduces_ops():
    """Fig. 4b: a shared decoded/augmented cache cuts preprocessing ops."""
    r_pt, _ = _run(PYTORCH, jobs=4, epochs=1)
    r_se, _ = _run(SENECA, jobs=4, epochs=1)
    assert r_se.preprocess_ops < r_pt.preprocess_ops
