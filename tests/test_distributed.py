"""Multi-device semantics (8 fake CPU devices via subprocess).

The suite's main process keeps 1 device (conftest guarantee), so anything
needing a mesh runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_dp_shard_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import registry
        from repro.configs.base import TRAIN_4K, ParallelismConfig
        from repro.models.model import build, make_batch
        from repro.train.optimizer import AdamW
        from repro.train.step import build_train_step
        from repro.train.dp_shard import build_dp_train_step
        from repro.train import compression

        cfg = registry.get_reduced('deepseek-7b')
        m = build(cfg)
        params = m.init(jax.random.key(0))
        opt = AdamW(lr=1e-3)
        batch = make_batch(jax.random.key(1), m, TRAIN_4K,
                           reduced_shape=(8, 16))
        # single device reference
        p1, s1 = params, opt.init(params)
        step1 = jax.jit(build_train_step(m, ParallelismConfig(), opt))
        for _ in range(3):
            p1, s1, m1 = step1(p1, s1, batch)
        # 4-way DP via shard_map
        mesh = Mesh(np.asarray(jax.devices()[:4]), ('data',))
        p2, s2 = params, opt.init(params)
        ef = compression.init_ef(params)
        step2 = jax.jit(build_dp_train_step(m, opt, mesh))
        for _ in range(3):
            p2, s2, ef, m2 = step2(p2, s2, ef, batch)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print('maxdiff', d)
        assert d < 5e-2, d
        print('loss1', float(m1['loss']), 'loss2', float(m2['loss']))
        assert abs(float(m1['loss']) - float(m2['loss'])) < 5e-2
    """)
    assert "maxdiff" in out


def test_compressed_dp_tracks_fp32():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import registry
        from repro.configs.base import TRAIN_4K
        from repro.models.model import build, make_batch
        from repro.train.optimizer import AdamW
        from repro.train.dp_shard import build_dp_train_step
        from repro.train import compression

        cfg = registry.get_reduced('qwen3-8b')
        m = build(cfg)
        params = m.init(jax.random.key(0))
        mesh = Mesh(np.asarray(jax.devices()[:4]), ('data',))
        opt = AdamW(lr=1e-3)
        batch = make_batch(jax.random.key(1), m, TRAIN_4K,
                           reduced_shape=(8, 16))
        losses = {}
        for comp in (False, True):
            p, s = params, opt.init(params)
            ef = compression.init_ef(params)
            step = jax.jit(build_dp_train_step(m, opt, mesh,
                                               compress_grads=comp))
            for _ in range(8):
                p, s, ef, metrics = step(p, s, ef, batch)
            losses[comp] = float(metrics['loss'])
        print(losses)
        assert abs(losses[True] - losses[False]) < 0.1
    """)


def test_pipeline_parallel_matches_stacked_scan():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.pp import pipeline_forward

        L, B, D = 8, 8, 16
        ks = jax.random.split(jax.random.key(0), 2)
        w = jax.random.normal(ks[0], (L, D, D)) * 0.3
        x = jax.random.normal(ks[1], (B, D))

        def block(wl, h):
            return jnp.tanh(h @ wl)

        def ref(w, x):
            def body(h, wl):
                return block(wl, h), None
            out, _ = jax.lax.scan(body, x, w)
            return out

        mesh = Mesh(np.asarray(jax.devices()[:4]), ('pipe',))
        out = pipeline_forward(block, w, x, mesh, microbatches=4)
        expect = ref(w, x)
        d = float(jnp.max(jnp.abs(out - expect)))
        print('pp maxdiff', d)
        assert d < 1e-5, d
    """)


def test_elastic_reshard_plan():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.elastic import make_mesh, reshard

        params = {'w': jnp.ones((16, 8)), 'b': jnp.ones((7,))}
        specs = {'w': P('data', None), 'b': P('data')}
        m8 = make_mesh(8, model_parallel=2)
        p8, plan8 = reshard(params, specs, m8)
        # b (7,) does not divide data=4 -> demoted to replication
        assert any('b' in d for d in plan8.demotions), plan8.demotions
        m4 = make_mesh(4, model_parallel=2)
        p4, plan4 = reshard(p8, specs, m4)
        np.testing.assert_array_equal(np.asarray(p4['w']),
                                      np.ones((16, 8)))
        print('elastic ok', plan4.summary())
    """)


def test_moe_ep_matches_local_dispatch():
    """Expert-parallel shard_map MoE == single-device sorted dispatch."""
    run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import registry
        from repro.configs.base import TRAIN_4K, ParallelismConfig
        from repro.distributed.compat import set_mesh
        from repro.distributed.sharding import make_rules, use_rules
        from repro.models.model import build, make_batch

        cfg = registry.get_reduced('deepseek-moe-16b')
        # drop-free capacity: local vs EP dispatch must then agree exactly
        # (with drops, per-shard capacity semantics legitimately differ)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=100.0))
        m = build(cfg)
        # fp32 params: distribution must be *exact* up to reduction order
        # (bf16 runs amplify ulp noise through the residual stream)
        params = m.init(jax.random.key(0), dtype=jnp.float32)
        batch = make_batch(jax.random.key(1), m, TRAIN_4K,
                           reduced_shape=(4, 16))
        batch.pop('labels')
        ref, _ = m.forward(params, batch)     # no mesh: local dispatch

        devs = np.asarray(jax.devices()[:8]).reshape(2, 4)
        mesh = Mesh(devs, ('data', 'model'))
        shape = TRAIN_4K
        par = ParallelismConfig(ep=True)
        rules = make_rules(cfg, shape, par, tp_size=4, dp_size=2, mesh=mesh)
        with use_rules(rules), set_mesh(mesh):
            out, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
        d = float(jnp.max(jnp.abs(ref - out)))
        print('moe ep maxdiff', d)
        assert d < 1e-4, d
    """)


def test_seq_parallel_ssd_matches_local():
    """Sequence-parallel SSD (models/ssm_sp.py): sharding S over 'model'
    with cross-rank state hand-off must reproduce the local block exactly
    (fp32)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import registry
        from repro.models import ssm as ssm_mod
        from repro.models.ssm_sp import ssm_block_seq_parallel
        from repro.models.params import init_params

        cfg = registry.get_reduced('mamba2-1.3b')
        defs = ssm_mod.ssm_defs(cfg)
        p = init_params(jax.random.key(0), defs, jnp.float32)
        B, S = 2, 64
        x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                              jnp.float32) * 0.5
        ref = ssm_mod.ssm_block(p, x, cfg)
        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                    ('data', 'model'))
        out = jax.jit(lambda p, x: ssm_block_seq_parallel(
            p, x, cfg, mesh, batch_axes=('data',)))(p, x)
        d = float(jnp.max(jnp.abs(ref - out)))
        print('sp-ssd maxdiff', d)
        assert d < 1e-4, d
    """)
