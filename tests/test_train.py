"""Optimizer / train-step / compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import TRAIN_4K, ParallelismConfig
from repro.models.model import build, make_batch
from repro.train import compression
from repro.train.optimizer import (AdamW, Quantized, _dequantize,
                                   _dequantize_pos, _quantize,
                                   _quantize_pos, warmup_cosine)
from repro.train.step import build_train_step


def _setup(arch="qwen3-8b", bs=(4, 32)):
    cfg = registry.get_reduced(arch)
    m = build(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=bs)
    return m, params, batch


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_loss_decreases(state_dtype):
    m, params, batch = _setup()
    opt = AdamW(lr=1e-3, state_dtype=state_dtype, eps=1e-6)
    state = opt.init(params)
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))
    first = None
    for _ in range(15):
        params, state, metrics = step(params, state, batch)
        first = first if first is not None else float(metrics["loss"])
    assert float(metrics["loss"]) < first - 0.5


def test_microbatch_grads_match_full_batch():
    m, params, batch = _setup(bs=(4, 16))
    g_full = jax.grad(lambda p: m.loss(p, batch))(params)
    mbs = jax.tree.map(lambda x: x.reshape((2, 2) + x.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, g_full)
    for i in range(2):
        mb = jax.tree.map(lambda x: x[i], mbs)
        g = jax.grad(lambda p: m.loss(p, mb))(params)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
    g_acc = jax.tree.map(lambda x: x / 2, g_acc)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=3e-2, rtol=3e-2)


def test_grad_clip_limits_norm():
    m, params, batch = _setup()
    opt = AdamW(lr=0.0, grad_clip=0.5)
    state = opt.init(params)
    step = build_train_step(m, ParallelismConfig(), opt)
    _, _, metrics = step(params, state, batch)
    assert float(metrics["grad_norm"]) > 0


def test_warmup_cosine_shape():
    f = warmup_cosine(1.0, warmup=10, total=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 0.11
    assert float(f(jnp.int32(100))) < 0.15
    assert float(f(jnp.int32(5))) < float(f(jnp.int32(10)))


def test_quantize_roundtrip_signed():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q = _quantize(x)
    err = jnp.max(jnp.abs(_dequantize(q, x.shape) - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_quantize_pos_dynamic_range():
    """Fourth-root coding must resolve values 6 decades below blockmax."""
    x = jnp.concatenate([jnp.full((128,), 1e-6), jnp.full((128,), 1.0)])
    q = _quantize_pos(x)
    back = _dequantize_pos(q, x.shape)
    assert float(back[0]) > 0, "small v must not collapse to 0"
    np.testing.assert_allclose(np.asarray(back[-1]), 1.0, rtol=0.02)


def test_compression_error_bound():
    g = jax.random.normal(jax.random.key(1), (513,))
    r = jnp.zeros_like(g)
    q, scale, new_r = compression.compress(g, r)
    deq = compression.decompress(q, scale, g.shape)
    assert float(jnp.max(jnp.abs(deq + new_r - g))) < 1e-5  # exact split
    assert float(jnp.max(jnp.abs(new_r))) <= float(
        jnp.max(jnp.abs(scale))) + 1e-6


def test_error_feedback_is_unbiased_over_steps():
    """Repeatedly compressing the same gradient with EF transmits its full
    magnitude over time (residual does not grow)."""
    g = jax.random.normal(jax.random.key(2), (300,)) * 1e-3
    r = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(50):
        q, s, r = compression.compress(g, r)
        sent = sent + compression.decompress(q, s, g.shape)
    np.testing.assert_allclose(np.asarray(sent / 50), np.asarray(g),
                               atol=1e-4)
