"""Concurrency stress tests (ISSUE-4 satellite) — ``slow``/``stress``
marked, excluded from tier-1 (pytest.ini) and run by the dedicated CI
stress job under a hard timeout.

The scenario the unit suite cannot afford: many sessions churning
(opening, pumping batches, closing) while the ``repartition="adaptive"``
background thread concurrently re-solves the MDP and resizes the live
TieredCache.  At quiesce: no deadlock (every thread joins), no lost
sessions (server bookkeeping returns to zero), and tier accounting is
exact (byte ledgers match entry sizes, capacities respected, ODS
metadata consistent with residency).
"""
import threading

import numpy as np
import pytest

from repro.api import JobSpec, SenecaServer, WorkloadRunner
from repro.api.server import CODE_FORM
from repro.cache.store import FORMS
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny

pytestmark = [pytest.mark.slow, pytest.mark.stress]


def _assert_quiesced_accounting(server: SenecaServer, n: int) -> None:
    svc = server.service
    cache = svc.cache
    with cache.lock:
        total_cap = 0
        for form in FORMS:
            part = cache.parts[form]
            assert part.stats.bytes_used == sum(part._sizes.values()), \
                f"{form}: byte ledger out of sync after churn"
            assert part.stats.bytes_used <= part.capacity, \
                f"{form}: over capacity after live resizes"
            assert set(part._data) == set(part._sizes)
            total_cap += part.capacity
        assert total_cap <= cache.capacity
        status = svc.backend.status_of(np.arange(n))
        for key in np.flatnonzero(status):
            form = CODE_FORM[int(status[key])]
            assert cache.parts[form].peek(int(key)) is not None, \
                f"stale ODS status {form} for evicted key {key}"


def test_session_churn_under_adaptive_background_repartitioning():
    """8 churn threads x 6 open/pump/close cycles against one adaptive
    server whose background tick thread re-solves and resizes live."""
    n = 512
    ds = tiny(n=n)
    server = SenecaServer.for_dataset(
        ds, cache_frac=0.35, seed=0, repartition="adaptive",
        repartition_period=0.02, repartition_cooldown=0.0,
        repartition_drift=0.01, repartition_gain=0.0,
        telemetry_min_samples=8)
    storage = RemoteStorage(ds)
    errors = []
    barrier = threading.Barrier(8)

    def churn(tid: int) -> None:
        try:
            barrier.wait(timeout=30)
            for cycle in range(6):
                sess = server.open_session(batch_size=8)
                pipe = DSIPipeline(sess, storage, n_workers=2,
                                   seed=tid * 100 + cycle)
                for _ in range(3):
                    batch = pipe.next_batch()
                    assert batch["images"].shape[0] == 8
                pipe.stop()             # closes the session
                assert sess.closed
        except Exception as e:          # noqa: BLE001 - surfaced below
            errors.append((tid, repr(e)))

    threads = [threading.Thread(target=churn, args=(i,), daemon=True)
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180.0)
    alive = [t.name for t in threads if t.is_alive()]
    assert not alive, f"deadlocked churn threads: {alive}"
    assert not errors, errors
    assert server.n_sessions == 0, "lost sessions after churn"
    assert server.service.backend.n_jobs == 1   # empty dict floor
    server.close()                      # stops the background thread
    _assert_quiesced_accounting(server, n)
    # the background thread genuinely ran: re-solves were triggered by
    # 48 session arrivals/departures plus drift ticks
    assert server.stats()["repartitions"]["resolves"] >= 8


def test_workload_runner_stress_many_jobs_adaptive():
    """A 12-job staggered trace through the WorkloadRunner against an
    adaptive server with a background tick thread: joins cleanly, counts
    every sample, and leaves exact tier accounting."""
    n = 256
    ds = tiny(n=n)
    server = SenecaServer.for_dataset(
        ds, cache_frac=0.35, seed=1, repartition="adaptive",
        repartition_period=0.05, repartition_cooldown=0.0,
        telemetry_min_samples=16)
    storage = RemoteStorage(ds, bandwidth=80e6)
    trace = [JobSpec(f"j{i}", arrival_s=0.05 * i, epochs=1,
                     batch_size=16, gpu_rate=2_000, n_workers=2)
             for i in range(12)]
    runner = WorkloadRunner(server, storage, record_ids=False)
    res = runner.run(trace, timeout=300)
    assert res.ok
    assert res.total_samples == 12 * n
    assert res.stats["n_sessions"] == 0
    server.close()
    _assert_quiesced_accounting(server, n)


def test_repeated_cancel_leaves_server_consistent():
    """Cancel storms: start a workload, cancel mid-flight, repeat on the
    same server — sessions never leak and the cache stays consistent."""
    n = 256
    ds = tiny(n=n)
    server = SenecaServer.for_dataset(ds, cache_frac=0.4, seed=2,
                                      repartition="on-change")
    storage = RemoteStorage(ds)
    for round_i in range(4):
        runner = WorkloadRunner(server, storage, record_ids=False)
        trace = [JobSpec(f"r{round_i}-j{i}", epochs=20, batch_size=16,
                         gpu_rate=400, n_workers=2) for i in range(3)]
        threading.Timer(0.3, runner.cancel).start()
        res = runner.run(trace, timeout=60, raise_on_error=False)
        assert all(j.cancelled or j.ok for j in res.jobs)
        assert server.n_sessions == 0, f"leaked sessions round {round_i}"
    server.close()
    _assert_quiesced_accounting(server, n)
