"""Stage-parallel DSI executor + prefetch-loop bugfixes.

Covers the ISSUE-3 contract: the prefetch queue neither drops nor
duplicates batches under a slow consumer, prefetch/refill failures are
recorded instead of swallowed, cache-hit fetch time is accounted as the
lookup interval, the batched augment backends (NumPy loop vs Pallas
kernel) agree within float tolerance with per-sample seed determinism,
and the stage-parallel executor preserves epoch semantics while emitting
batches in sampling order.
"""
import time

import numpy as np
import pytest

from repro.api import (AZURE_NC96, SenecaServer, TelemetryAggregator,
                       resolve_augment_backend)
from repro.data.pipeline import DSIPipeline, plan_stage_workers
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny

BACKENDS = ("numpy", "pallas")


def _server(ds, **kw):
    kw.setdefault("cache_frac", 0.4)
    return SenecaServer.for_dataset(ds, hardware=AZURE_NC96, seed=1, **kw)


# ----------------------------------------------------------------------
# satellite bugfix: prefetch holds the built batch under a slow consumer
def test_prefetch_slow_consumer_no_drop_no_dup():
    ds = tiny(n=120)
    server = _server(ds, use_ods=False)          # naive: exact epoch cover
    pipe = DSIPipeline(server.open_session(batch_size=20), RemoteStorage(ds),
                       n_workers=2, prefetch=1)
    pipe.start_prefetch()
    seen = []
    for _ in range(120 // 20):
        time.sleep(0.05)                         # slower than production
        seen.extend(pipe.get(timeout=30.0)["ids"].tolist())
    # the seed dropped every batch built while the queue was full, so a
    # slow consumer skipped sample ids; held-and-reoffered batches cover
    # the first epoch exactly, in order, no gaps and no duplicates
    assert sorted(seen) == list(range(120)), \
        "prefetch dropped or duplicated batches under a slow consumer"
    pipe.stop()
    server.close()


def test_prefetch_records_next_batch_exception():
    ds = tiny(n=64)
    server = _server(ds)
    pipe = DSIPipeline(server.open_session(batch_size=8), RemoteStorage(ds),
                       n_workers=2, prefetch=2)

    def boom():
        raise RuntimeError("synthetic next_batch failure")
    pipe.next_batch = boom
    pipe.start_prefetch()
    with pytest.raises(RuntimeError, match="prefetch thread died"):
        pipe.get(timeout=10.0)
    assert server.stats()["telemetry"]["errors"]["prefetch"] == 1
    pipe.stop()
    server.close()


# ----------------------------------------------------------------------
# satellite bugfix: cache-hit fetch time is the lookup interval
def test_hit_fetch_time_accounts_lookup_interval():
    ds = tiny(n=32)
    server = _server(ds, split=(0.0, 0.0, 1.0))
    sess = server.open_session(batch_size=4)
    pipe = DSIPipeline(sess, RemoteStorage(ds), n_workers=1)
    out = np.zeros((*ds.crop_hw, 3), np.float32)
    assert sess.admit(3, "augmented", out, out.nbytes)

    # the pipeline's serving seam is lookup_tiered (it also names the
    # tier that answered, for per-tier bandwidth telemetry)
    orig = pipe.session.lookup_tiered

    def slow_lookup(sid):
        time.sleep(0.02)
        return orig(sid)
    pipe.session.lookup_tiered = slow_lookup
    got = pipe._produce_sample(3, epoch_tag=0)
    assert got is out or np.array_equal(got, out)
    # the seed charged ~0 here (timer started after the lookup returned)
    assert pipe.times.fetch >= 0.015, pipe.times.fetch
    pipe.session.lookup_tiered = orig
    pipe.stop()
    server.close()


# ----------------------------------------------------------------------
# satellite bugfix: refill failures are counted, not swallowed
def test_refill_errors_surface_in_stats():
    ds = tiny(n=32)
    server = _server(ds)
    pipe = DSIPipeline(server.open_session(batch_size=4), RemoteStorage(ds),
                       n_workers=1)

    def bad_fetch(sid):
        raise IOError("storage down")
    pipe.storage.fetch = bad_fetch
    pipe._refill_one(5)
    pipe._refill_one(6)
    st = server.stats()
    assert st["refill_errors"] == 2
    assert st["telemetry"]["errors"]["refill"] == 2
    pipe.stop()
    server.close()


# ----------------------------------------------------------------------
# batched augment backends: parity + per-sample seed determinism
@pytest.mark.parametrize("backend", BACKENDS)
def test_augment_backend_seed_determinism(backend):
    """Same seed -> same output row, independent of batch composition
    (the stage executor's augment groups vary with cache hits)."""
    be = resolve_augment_backend(backend)
    rng = np.random.default_rng(0)
    imgs = rng.integers(0, 256, size=(7, 48, 40, 3), dtype=np.uint8)
    seeds = (np.arange(7) * 977 + 13).astype(np.int64)
    full = be.augment_batch(imgs, (32, 24), seeds)
    assert full.shape == (7, 32, 24, 3) and full.dtype == np.float32
    for i in (0, 3, 6):                 # singleton batches (bucket B=1)
        solo = be.augment_batch(imgs[i:i + 1], (32, 24), seeds[i:i + 1])
        np.testing.assert_allclose(solo[0], full[i], atol=2e-6)


def test_augment_backend_parity_numpy_vs_pallas():
    rng = np.random.default_rng(7)
    imgs = rng.integers(0, 256, size=(6, 64, 64, 3), dtype=np.uint8)
    seeds = (np.arange(6) * 1_000_003 + 42).astype(np.int64)
    out_np = resolve_augment_backend("numpy").augment_batch(
        imgs, (56, 56), seeds)
    out_pl = resolve_augment_backend("pallas").augment_batch(
        imgs, (56, 56), seeds)
    np.testing.assert_allclose(out_pl, out_np, atol=2e-6)


def test_augment_backend_registry_errors():
    with pytest.raises(ValueError, match="unknown augment backend"):
        resolve_augment_backend("nope")
    with pytest.raises(TypeError, match="AugmentBackend"):
        resolve_augment_backend(object())
    # "jax" is accepted as an alias for the Pallas kernel path
    assert resolve_augment_backend("jax").name == "pallas"


# ----------------------------------------------------------------------
# stage-parallel executor semantics
@pytest.mark.parametrize("augment_backend", BACKENDS)
def test_stage_parallel_epoch_coverage_in_order(augment_backend):
    ds = tiny(n=96)
    server = _server(ds, use_ods=False)          # naive: exact epoch cover
    pipe = DSIPipeline(server.open_session(batch_size=12), RemoteStorage(ds),
                       n_workers=4, executor="stage-parallel",
                       augment_backend=augment_backend)
    seen = []
    for _ in range(96 // 12):
        b = pipe.next_batch()
        assert b["images"].shape == (12, *ds.crop_hw, 3)
        assert b["labels"].shape == (12,)
        assert np.isfinite(b["images"]).all()
        assert abs(float(b["images"].mean())) < 2.0
        seen.extend(b["ids"].tolist())
    assert sorted(seen) == list(range(96)), \
        "stage-parallel executor dropped/duplicated samples"
    pipe.stop()
    server.close()


def test_stage_parallel_matches_per_sample_content():
    """Both executors produce identical tensors for a given sample id
    (numpy augment backend: bit-identical; seeds are per-sample).

    The augmented tier is disabled (encoded-only split): background
    refills admit entries under their own seed, and whether a sample is
    served from a refill is a thread race — with no augmented tier every
    sample is augmented fresh from its (epoch, sid) seed.
    """
    def run(executor):
        ds = tiny(n=48)
        server = _server(ds, use_ods=False, split=(1.0, 0.0, 0.0))
        pipe = DSIPipeline(server.open_session(batch_size=8),
                           RemoteStorage(ds), n_workers=3,
                           executor=executor)
        out = {}
        for _ in range(48 // 8):
            b = pipe.next_batch()
            for i, sid in enumerate(b["ids"].tolist()):
                out[sid] = b["images"][i]
        pipe.stop()
        server.close()
        return out

    a, b = run("per-sample"), run("stage-parallel")
    assert a.keys() == b.keys()
    for sid in a:
        np.testing.assert_array_equal(a[sid], b[sid])


def test_stage_parallel_reports_queue_gauges():
    ds = tiny(n=64)
    server = _server(ds)
    pipe = DSIPipeline(server.open_session(batch_size=8), RemoteStorage(ds),
                       n_workers=4, executor="stage-parallel")
    for _ in range(4):
        pipe.next_batch()
    tel = server.stats()["telemetry"]
    assert set(tel["queue_occupancy"]) == \
        {"fetch", "decode", "augment", "collate", "out"}
    assert all(0.0 <= v <= 1.0 for v in tel["queue_occupancy"].values())
    assert "queue_depth" in tel
    pipe.stop()
    server.close()


def test_stage_parallel_session_close_fails_fast():
    """Closing the session externally must surface as SessionClosed from
    the consumer promptly (the per-sample executor's behavior), not as a
    full get_batch timeout."""
    from repro.api import SessionClosed
    ds = tiny(n=64)
    server = _server(ds)
    sess = server.open_session(batch_size=8)
    pipe = DSIPipeline(sess, RemoteStorage(ds), n_workers=2,
                       executor="stage-parallel", prefetch=1)
    pipe.next_batch()
    sess.close()
    with pytest.raises(SessionClosed):
        # drain whatever was in flight, then the closed session surfaces
        for _ in range(20):
            pipe.next_batch()
    pipe.stop()
    server.close()


def test_stage_worker_counts_scale_calibration_rates():
    """t_a/t_da conversion honors per-stage worker counts: a single
    augment thread must not be scaled by the global concurrency."""
    tel = TelemetryAggregator()
    tel.add_concurrency(4)
    for _ in range(4):
        tel.record_stage("decode", 0.010, workers=2)
        tel.record_stage("augment", 0.020, workers=1)
    snap = tel.snapshot()
    assert snap.t_a == pytest.approx(1 / 0.020)          # 1 thread
    # pipelined chain rate: min(2/0.010, 1/0.020) = 50
    assert snap.t_da == pytest.approx(min(2 / 0.010, 1 / 0.020))
    # without per-stage counts the seed semantics hold (conc-scaled)
    tel2 = TelemetryAggregator()
    tel2.add_concurrency(4)
    tel2.record_stage("decode", 0.010)
    tel2.record_stage("augment", 0.020)
    snap2 = tel2.snapshot()
    assert snap2.t_a == pytest.approx(4 / 0.020)
    assert snap2.t_da == pytest.approx(4 / 0.030)


def test_unknown_executor_rejected():
    ds = tiny(n=16)
    server = _server(ds)
    with pytest.raises(ValueError, match="unknown executor"):
        DSIPipeline(server.open_session(batch_size=4), RemoteStorage(ds),
                    executor="warp-speed")
    # legacy call style: validation must fire BEFORE the job registers,
    # or the failed constructor leaks a phantom job into the shared
    # service (inflating the refcount-eviction threshold)
    with pytest.raises(ValueError, match="unknown executor"):
        DSIPipeline(7, server.service, RemoteStorage(ds), 4,
                    executor="warp-speed")
    assert 7 not in server.service._samplers
    server.close()


def test_executor_stop_clears_stage_worker_scaling():
    """A stopped stage-parallel executor must not leave its group sizes
    scaling latencies reported by later per-sample pipelines."""
    ds = tiny(n=64)
    server = _server(ds)
    pipe = DSIPipeline(server.open_session(batch_size=8), RemoteStorage(ds),
                       n_workers=4, executor="stage-parallel")
    pipe.next_batch()
    assert server.service.telemetry._stage_workers   # set while running
    pipe.stop()
    assert not server.service.telemetry._stage_workers
    server.close()


# ----------------------------------------------------------------------
# telemetry-driven worker-group sizing
def test_plan_stage_workers_splits_by_stage_ewmas():
    tel = TelemetryAggregator()
    # no data: even split, fetch 2x-oversubscribed (IO-bound group)
    assert plan_stage_workers(tel, 4) == (4, 2)
    tel.record_stage("fetch_storage", 0.03)
    tel.record_stage("decode", 0.01)
    assert plan_stage_workers(tel, 4) == (6, 1)     # fetch-bound
    tel2 = TelemetryAggregator()
    tel2.record_stage("fetch_storage", 0.001)
    tel2.record_stage("decode", 0.099)
    assert plan_stage_workers(tel2, 6) == (2, 5)    # decode-bound, >=1
    assert plan_stage_workers(tel2, 1) == (2, 1)    # budget floor of 2


def test_stage_parallel_elastic_groups_track_telemetry():
    """The executor re-plans its fetch/decode groups from the stage EWMAs
    every batch: targets track the plan (within the +-1 anti-churn
    hysteresis plus the EWMA movement since the last batch)."""
    ds = tiny(n=128)
    server = _server(ds)
    pipe = DSIPipeline(server.open_session(batch_size=8), RemoteStorage(ds),
                       n_workers=4, executor="stage-parallel")
    for _ in range(6):
        pipe.next_batch()
    counts = pipe._executor.worker_counts()
    assert counts["fetch"] >= 1 and counts["decode"] >= 1
    pipe.stop()                     # freeze telemetry before comparing
    server.close()
    planned = plan_stage_workers(server.service.telemetry, 4)
    target = pipe._executor._target
    assert abs(target["fetch"] - planned[0]) <= 2
    assert abs(target["decode"] - planned[1]) <= 2


# ----------------------------------------------------------------------
# device executor semantics (fused Pallas decode+augment + HBM tier)
def test_device_executor_epoch_coverage_and_bitwise_parity():
    """One epoch through the device route, augmented/decoded tiers
    disabled so every sample takes the fused kernel fresh: batch rows
    must equal decode + Pallas augment_batch_seeded *bitwise* (the
    kernel parity contract, here exercised through the live stack)."""
    from repro.data.pipeline import _aug_seed
    from repro.kernels.augment.ops import augment_batch_seeded
    ds = tiny(n=64)
    server = _server(ds, use_ods=False, split=(1.0, 0.0, 0.0))
    sess = server.open_session(batch_size=8)
    pipe = DSIPipeline(sess, RemoteStorage(ds), n_workers=2,
                       executor="device")
    seen = []
    for _ in range(64 // 8):
        epoch = sess.epoch
        b = pipe.next_batch()
        assert b["images"].shape == (8, *ds.crop_hw, 3)
        ids = b["ids"].tolist()
        seen.extend(ids)
        imgs = np.stack([ds.decode(ds.encoded(s), s) for s in ids])
        seeds = np.asarray([_aug_seed(epoch, s) for s in ids], np.int64)
        ref = augment_batch_seeded(imgs, seeds, *ds.crop_hw)
        np.testing.assert_array_equal(np.asarray(b["images"]), ref)
    assert sorted(seen) == list(range(64)), \
        "device executor dropped/duplicated samples"
    pipe.stop()
    server.close()


def test_device_executor_hbm_hits_are_zero_h2d():
    """With an HBM tier large enough for every augmented sample, the
    second epoch serves device-resident rows: no bytes cross the h2d
    channel and the HBM tier reports hits."""
    ds = tiny(n=64)
    hbm = int(1.2 * 64 * ds.augmented_bytes())
    server = _server(ds, use_ods=False, split=(0.5, 0.0, 0.5),
                     device_cache_bytes=hbm, hbm_split=(0.0, 0.0, 1.0))
    sess = server.open_session(batch_size=8)
    pipe = DSIPipeline(sess, RemoteStorage(ds), n_workers=2,
                       executor="device")
    tel = server.service.telemetry
    for _ in range(64 // 8):                      # epoch 1: all fresh
        pipe.next_batch()
    h2d_after_e1 = tel.channel_total_bytes("h2d")
    for _ in range(64 // 8):                      # epoch 2: all HBM hits
        b = pipe.next_batch()
        assert b["images"].shape == (8, *ds.crop_hw, 3)
    assert tel.channel_total_bytes("h2d") == h2d_after_e1, \
        "HBM-hit epoch shipped host->device payload bytes"
    stats = server.stats()
    assert stats["residency_counts"]["hbm"] == 64
    assert stats["hbm"]["augmented"]["hbm_hits"] > 0
    pipe.stop()
    server.close()


def test_device_executor_rejects_non_fusable_dataset():
    from repro.data.synthetic import DecodeHeavyDataset
    ds = DecodeHeavyDataset("h", 32, 1024)
    server = _server(ds, use_ods=False)
    with pytest.raises(ValueError, match="device executor"):
        DSIPipeline(server.open_session(batch_size=8), RemoteStorage(ds),
                    executor="device")
    server.close()


def test_device_executor_decoded_hbm_hit_stays_on_device():
    """A decoded-form value served from the HBM tier (hbm_split with
    z_d > 0) must be augmented on device: no d2h download metered on
    the cache channel, no re-upload on h2d, and the rows still match
    the host decode+augment reference bitwise."""
    from repro.data.pipeline import _aug_seed
    from repro.kernels.augment.ops import augment_batch_seeded
    ds = tiny(n=32)
    hbm = int(1.2 * 32 * ds.decoded_bytes())
    server = _server(ds, use_ods=False, split=(0.0, 1.0, 0.0),
                     device_cache_bytes=hbm, hbm_split=(0.0, 1.0, 0.0))
    sess = server.open_session(batch_size=8)
    # pre-warm every sample's decoded form; array payloads the HBM tier
    # admits go device-resident immediately
    for sid in range(32):
        img = ds.decode(ds.encoded(sid), sid)
        assert sess.admit(sid, "decoded", img, img.nbytes)
    assert server.stats()["hbm"]["decoded"]["hbm_entries"] == 32
    pipe = DSIPipeline(sess, RemoteStorage(ds), n_workers=2,
                      executor="device")
    tel = server.service.telemetry
    seen = []
    for _ in range(32 // 8):
        epoch = sess.epoch
        b = pipe.next_batch()
        ids = b["ids"].tolist()
        seen.extend(ids)
        imgs = np.stack([ds.decode(ds.encoded(s), s) for s in ids])
        seeds = np.asarray([_aug_seed(epoch, s) for s in ids], np.int64)
        ref = augment_batch_seeded(imgs, seeds, *ds.crop_hw)
        np.testing.assert_array_equal(np.asarray(b["images"]), ref)
    assert sorted(seen) == list(range(32))
    assert tel.channel_total_bytes("cache") == 0, \
        "decoded HBM hit metered a device->host download as cache bytes"
    assert tel.channel_total_bytes("h2d") == 0, \
        "decoded HBM hit re-uploaded device-resident pixels"
    pipe.stop()
    server.close()
