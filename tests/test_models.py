"""Per-arch smoke tests (reduced configs) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import TRAIN_4K, MoEConfig
from repro.models.model import build, make_batch
from repro.models.params import padded_vocab

ARCHS = registry.list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes + no NaNs (deliverable
    f: reduced-config smoke test per assigned architecture)."""
    cfg = registry.get_reduced(arch)
    m = build(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 32))
    logits, aux = m.forward(params, {k: v for k, v in batch.items()
                                     if k != "labels"})
    if cfg.family == "encoder":
        assert logits.shape == (2, cfg.n_classes)
    else:
        assert logits.shape[0] == 2 and \
            logits.shape[-1] == padded_vocab(cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))

    loss = m.loss(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: m.loss(p, batch))(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get(a).has_decoder])
def test_decode_step_shapes(arch):
    cfg = registry.get_reduced(arch)
    m = build(cfg)
    params = m.init(jax.random.key(0))
    cache = m.init_cache(batch=2, s_max=64)
    logits, cache2 = m.decode_step(params, cache,
                                   jnp.ones((2, 1), jnp.int32),
                                   jnp.int32(3))
    assert logits.shape[:2] == (2, 1)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen3-8b", "qwen1.5-32b",
                                  "seamless-m4t-large-v2"])
def test_prefill_decode_matches_forward(arch):
    """Attention-family consistency: prefill cache + decode_step(S) equals
    forward on the extended sequence (exactness, not allclose)."""
    cfg = registry.get_reduced(arch)
    m = build(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(B, S))
    batch.pop("labels", None)
    cache = m.init_cache(batch=B, s_max=S + 4)
    logits_pf, cache = m.prefill(params, batch, cache)
    full, _ = m.forward(params, batch)
    np.testing.assert_array_equal(np.asarray(logits_pf), np.asarray(full))

    nxt = jnp.full((B, 1), 3, jnp.int32)
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    full2, _ = m.forward(params, ext)
    dec, _ = m.decode_step(params, cache, nxt, jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(full2[:, -1], np.float32),
        np.asarray(dec[:, 0], np.float32), atol=1e-2, rtol=1e-2)


def test_moe_decode_exact_without_drops():
    cfg = registry.get_reduced("deepseek-moe-16b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=100.0))
    m = build(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 12
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(B, S))
    batch.pop("labels", None)
    cache = m.init_cache(batch=B, s_max=S + 2)
    _, cache = m.prefill(params, batch, cache)
    nxt = jnp.full((B, 1), 5, jnp.int32)
    ext = {"tokens": jnp.concatenate([batch["tokens"], nxt], 1)}
    full2, _ = m.forward(params, ext)
    dec, _ = m.decode_step(params, cache, nxt, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(full2[:, -1], np.float32),
                               np.asarray(dec[:, 0], np.float32),
                               atol=1e-3)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b"])
def test_ssm_decode_trajectory_matches_forward(arch):
    """Recurrent-state consistency: decoding token-by-token from scratch
    reproduces the chunked-SSD forward logits at every position."""
    cfg = registry.get_reduced(arch)
    m = build(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    full, _ = m.forward(params, {"tokens": toks})
    cache = m.init_cache(batch=B, s_max=S)
    outs = []
    for t in range(S):
        logits, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    # bf16 logits: tolerance is ~2 ulp at logit scale (no growth over
    # positions = the recurrence itself is exact; see git history)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=1.5e-1, rtol=5e-2)
    # and the trajectories agree on the argmax almost everywhere
    agree = np.mean(np.argmax(np.asarray(dec, np.float32), -1) ==
                    np.argmax(np.asarray(full, np.float32), -1))
    assert agree >= 0.9, agree


def test_vocab_padding_masked_in_loss():
    cfg = registry.get_reduced("qwen3-8b")
    m = build(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    logits, _ = m.forward(params, {"tokens": batch["tokens"]})
    # padded logits exist but must never win the softmax after masking
    assert logits.shape[-1] == padded_vocab(cfg.vocab_size)
    loss = m.loss(params, batch)
    assert float(loss) < jnp.log(padded_vocab(cfg.vocab_size)) + 1.0


def test_label_ignore_index():
    from repro.models.transformer import cross_entropy
    logits = jax.random.normal(jax.random.key(0), (2, 4, 32))
    labels = jnp.array([[1, 2, -1, -1], [3, -1, -1, -1]])
    ce = cross_entropy(logits, labels, 32)
    ce_full = cross_entropy(logits, jnp.abs(labels), 32)
    assert np.isfinite(float(ce)) and float(ce) != float(ce_full)


def test_param_counts_match_analytic():
    """ParamDef totals track ModelConfig.n_params within a few %."""
    for arch in ("qwen3-8b", "deepseek-7b", "mamba2-1.3b"):
        cfg = registry.get(arch)
        m = build(cfg)
        analytic = cfg.n_params()
        # padded vocab inflates the defs count; bound the gap
        defs = m.n_params()
        assert abs(defs - analytic) / analytic < 0.05, arch
