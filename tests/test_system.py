"""End-to-end behaviour: Seneca-fed training on CPU, real pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ParallelismConfig
from repro.launch.train import image_batch_source, lm_batch_source
from repro.models.model import build
from repro.train.optimizer import AdamW
from repro.train.step import build_train_step


def test_vit_trains_on_real_seneca_pipeline():
    """The paper's actual workload shape: an image classifier fed by the
    threaded DSI pipeline (storage -> MDP-partitioned cache -> ODS ->
    augment) while training for real."""
    cfg = registry.get_reduced("vit-huge")
    model = build(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=2e-3)
    state = opt.init(params)
    step = jax.jit(build_train_step(model, ParallelismConfig(), opt))
    source, pipe, server = image_batch_source(model, batch=16)
    losses = []
    for _ in range(12):
        params, state, metrics = step(params, state, source())
        losses.append(float(metrics["loss"]))
    pipe.stop()
    assert all(np.isfinite(losses))
    stats = server.stats()
    assert stats["hits"] + stats["misses"] > 0
    assert stats["cache_bytes_used"] > 0
    # three-tier partition was actually applied (facade stats expose the
    # per-tier occupancy derived from TieredCache.status_array)
    assert sorted(stats["tier_counts"]) == ["augmented", "decoded",
                                            "encoded"]
    assert sum(stats["tier_counts"].values()) > 0


def test_lm_end_to_end_converges():
    cfg = registry.get_reduced("qwen3-8b")
    model = build(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    step = jax.jit(build_train_step(model, ParallelismConfig(), opt))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(4, 33), dtype=np.int64)
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    first = None
    for _ in range(15):
        params, state, metrics = step(params, state, batch)
        first = first if first is not None else float(metrics["loss"])
    assert float(metrics["loss"]) < first - 1.0


def test_serving_generates_tokens():
    from repro.serve.step import Request, Server
    cfg = registry.get_reduced("deepseek-7b")
    model = build(cfg)
    params = model.init(jax.random.key(0))
    server = Server(model, params, n_slots=2, s_max=48)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=6), max_new=4)
            for i in range(2)]
    for r in reqs:
        assert server.add_request(r)
    rounds = 0
    while server.decode_round() and rounds < 20:
        rounds += 1
    assert all(len(r.generated) >= 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size
               for r in reqs for t in r.generated)
