"""Checkpoint + fault-tolerance behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import TRAIN_4K, ParallelismConfig
from repro.distributed import checkpoint as ckpt
from repro.distributed.ft import FTConfig, ResilientTrainer
from repro.models.model import build, make_batch
from repro.train.optimizer import AdamW
from repro.train.step import build_train_step


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.zeros((3,), jnp.int8)}}


def test_roundtrip_exact(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    back, manifest = ckpt.restore(str(tmp_path), t)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_pointer_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.latest_step(str(tmp_path)) == 4
    removed = ckpt.prune(str(tmp_path), keep=2)
    assert len(removed) == 2
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones((3, 3))})


def test_resilient_trainer_survives_failures(tmp_path):
    """Inject failures mid-run; the final state must equal a failure-free
    run (determinism of restore + fixed batch stream)."""
    cfg = registry.get_reduced("deepseek-7b")
    m = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))

    def mk_trainer(dirname, injector=None):
        params = m.init(jax.random.key(0))
        return ResilientTrainer(
            step_fn=step, params=params, opt_state=opt.init(params),
            cfg=FTConfig(ckpt_dir=str(tmp_path / dirname), ckpt_every=5,
                         max_restarts=5),
            batch_source=lambda: batch, failure_injector=injector)

    clean = mk_trainer("clean")
    clean.run(20)

    fails = {12: True, 17: True}
    faulty = mk_trainer("faulty",
                        injector=lambda s: fails.pop(s, False))
    faulty.run(20)
    assert faulty.restarts == 2
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(faulty.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_resume_after_interrupt(tmp_path):
    cfg = registry.get_reduced("deepseek-7b")
    m = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))
    params = m.init(jax.random.key(0))
    t1 = ResilientTrainer(step, params, opt.init(params),
                          FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5),
                          batch_source=lambda: batch)
    t1.run(10)      # writes step_10
    t2 = ResilientTrainer(step, m.init(jax.random.key(9)),
                          opt.init(params),
                          FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5),
                          batch_source=lambda: batch)
    t2.run(12)      # must resume from 10, not retrain from 0
    assert t2.step == 12
    assert len(t2.history) == 2
