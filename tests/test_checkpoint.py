"""Checkpoint + fault-tolerance behaviour."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import TRAIN_4K, ParallelismConfig
from repro.distributed import checkpoint as ckpt
from repro.distributed.ft import FTConfig, ResilientTrainer
from repro.models.model import build, make_batch
from repro.train.optimizer import AdamW
from repro.train.step import build_train_step


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.zeros((3,), jnp.int8)}}


def test_roundtrip_exact(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    back, manifest = ckpt.restore(str(tmp_path), t)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_pointer_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.latest_step(str(tmp_path)) == 4
    removed = ckpt.prune(str(tmp_path), keep=2)
    assert len(removed) == 2
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones((3, 3))})


def test_latest_step_skips_truncated_manifest(tmp_path):
    t = _tree()
    for s in (1, 2, 3):
        ckpt.save(str(tmp_path), s, t)
    # crash-truncate the newest manifest: LATEST points at garbage
    mpath = tmp_path / "step_00000003" / "manifest.json"
    mpath.write_text(mpath.read_text()[:20])
    assert ckpt.latest_step(str(tmp_path)) == 2
    back, manifest = ckpt.restore(str(tmp_path), t)
    assert manifest["step"] == 2
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_mixed_validity(tmp_path):
    """Restore picks the newest *complete* checkpoint across a mix of
    valid, truncated-npz, missing-manifest, and missing-key dirs."""
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t)
    # 5: truncated arrays.npz (crash mid-write after rename — bad zip)
    npz = tmp_path / "step_00000005" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:10])
    # 4: manifest deleted outright
    (tmp_path / "step_00000004" / "manifest.json").unlink()
    # 3: manifest claims a key the npz doesn't have
    m = tmp_path / "step_00000003" / "manifest.json"
    doc = json.loads(m.read_text())
    doc["keys"].append("ghost/leaf")
    m.write_text(json.dumps(doc))
    assert ckpt.latest_step(str(tmp_path)) == 2
    _back, manifest = ckpt.restore(str(tmp_path), t)
    assert manifest["step"] == 2


def test_latest_step_stale_pointer(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 2, t)
    # LATEST names a dir that prune already removed
    (tmp_path / "LATEST").write_text("step_00000009")
    assert ckpt.latest_step(str(tmp_path)) == 2
    # no checkpoints at all -> None / FileNotFoundError
    empty = tmp_path / "empty"
    empty.mkdir()
    assert ckpt.latest_step(str(empty)) is None
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(empty), t)


def test_restore_survives_prune_race(tmp_path, monkeypatch):
    """A checkpoint vanishing between selection and read (prune racing
    restore) must fall through to an older survivor, not crash."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 2, t)
    real = ckpt._restore_path
    calls = {"n": 0}

    def racy(path, template):
        calls["n"] += 1
        if calls["n"] == 1 and path.endswith("step_00000002"):
            import shutil as _sh
            _sh.rmtree(path)          # prune wins the race on attempt 1
            raise FileNotFoundError(path)
        return real(path, template)

    monkeypatch.setattr(ckpt, "_restore_path", racy)
    _back, manifest = ckpt.restore(str(tmp_path), t)
    assert manifest["step"] == 1
    assert calls["n"] == 2


def test_resilient_trainer_survives_failures(tmp_path):
    """Inject failures mid-run; the final state must equal a failure-free
    run (determinism of restore + fixed batch stream)."""
    cfg = registry.get_reduced("deepseek-7b")
    m = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))

    def mk_trainer(dirname, injector=None):
        params = m.init(jax.random.key(0))
        return ResilientTrainer(
            step_fn=step, params=params, opt_state=opt.init(params),
            cfg=FTConfig(ckpt_dir=str(tmp_path / dirname), ckpt_every=5,
                         max_restarts=5),
            batch_source=lambda: batch, failure_injector=injector)

    clean = mk_trainer("clean")
    clean.run(20)

    fails = {12: True, 17: True}
    faulty = mk_trainer("faulty",
                        injector=lambda s: fails.pop(s, False))
    faulty.run(20)
    assert faulty.restarts == 2
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(faulty.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_trainer_restart_without_checkpoint_resets_to_step0(tmp_path):
    """A failure before the first checkpoint restores the initial state
    (step 0) instead of crashing on the empty checkpoint dir — and the
    final params still match a failure-free run."""
    cfg = registry.get_reduced("deepseek-7b")
    m = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))

    def mk_trainer(dirname, injector=None):
        params = m.init(jax.random.key(0))
        return ResilientTrainer(
            step_fn=step, params=params, opt_state=opt.init(params),
            cfg=FTConfig(ckpt_dir=str(tmp_path / dirname), ckpt_every=50,
                         max_restarts=3),
            batch_source=lambda: batch, failure_injector=injector)

    clean = mk_trainer("clean")
    clean.run(6)
    fails = {3: True}                # fires before any checkpoint exists
    faulty = mk_trainer("faulty", injector=lambda s: fails.pop(s, False))
    faulty.run(6)
    assert faulty.restarts == 1
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(faulty.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_trainer_restart_on_corrupt_checkpoint(tmp_path):
    """All checkpoints corrupt -> graceful reset to step 0, no raise."""
    cfg = registry.get_reduced("deepseek-7b")
    m = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))
    params = m.init(jax.random.key(0))
    t = ResilientTrainer(step, params, opt.init(params),
                         FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                  max_restarts=3),
                         batch_source=lambda: batch)
    t.run(4)                         # writes step_2, step_4
    for d in tmp_path.glob("step_*"):
        (d / "manifest.json").write_text("{")
    t._restart()
    assert t.step == 0 and t.restarts == 1
    t.run(6)                         # trains forward again from scratch
    assert t.step == 6


def test_trainer_consults_failed_hosts(tmp_path):
    """A host marked dead in the heartbeat registry triggers a restore
    before the next step and is re-admitted afterwards."""
    cfg = registry.get_reduced("deepseek-7b")
    m = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))
    params = m.init(jax.random.key(0))
    t = ResilientTrainer(step, params, opt.init(params),
                         FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                  max_restarts=3),
                         batch_source=lambda: batch)
    t.run(4)
    t.heartbeats.mark_dead(7)        # fault injector reports host 7 gone
    t.run(8)
    assert t.restarts == 1
    assert t.step == 8
    assert not t.heartbeats.is_dead(7)   # re-admitted after restore


def test_trainer_restart_budget_exhausted(tmp_path):
    cfg = registry.get_reduced("deepseek-7b")
    m = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))
    params = m.init(jax.random.key(0))
    t = ResilientTrainer(step, params, opt.init(params),
                         FTConfig(ckpt_dir=str(tmp_path), max_restarts=1),
                         batch_source=lambda: batch,
                         failure_injector=lambda s: True)
    with pytest.raises(RuntimeError, match="restart budget"):
        t.run(4)


def test_resume_after_interrupt(tmp_path):
    cfg = registry.get_reduced("deepseek-7b")
    m = build(cfg)
    opt = AdamW(lr=1e-3)
    batch = make_batch(jax.random.key(1), m, TRAIN_4K, reduced_shape=(2, 16))
    step = jax.jit(build_train_step(m, ParallelismConfig(), opt))
    params = m.init(jax.random.key(0))
    t1 = ResilientTrainer(step, params, opt.init(params),
                          FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5),
                          batch_source=lambda: batch)
    t1.run(10)      # writes step_10
    t2 = ResilientTrainer(step, m.init(jax.random.key(9)),
                          opt.init(params),
                          FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5),
                          batch_source=lambda: batch)
    t2.run(12)      # must resume from 10, not retrain from 0
    assert t2.step == 12
    assert len(t2.history) == 2
