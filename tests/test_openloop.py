"""Open-loop serving + the clock-correctness bugfixes that make its
latency accounting exact.

Covers the ISSUE-9 contract: exact nearest-rank percentile math;
seed-reproducible arrival schedules; a VirtualClock burst trace whose
per-request latencies (and p99) are identical across two fresh runs —
with storage-stall time flowing through the clock-aware token bucket;
an overload trace where SLO admission control sheds/degrades instead of
growing the queue without bound; and regressions for the three
satellite bugfixes (token-bucket pacing through the pluggable clock,
repartition cooldown on the service clock, sub-poll ``get`` timeouts).
"""
import queue
import time

import numpy as np
import pytest

from repro.api import SLO, SenecaServer
from repro.api.telemetry import TelemetryAggregator, quantile
from repro.data.pipeline import DSIPipeline
from repro.data.storage import BandwidthBudget, RemoteStorage
from repro.data.synthetic import SyntheticDataset, tiny
from repro.workload import (OpenLoopGenerator, VirtualClock,
                            bursty_arrivals, diurnal_arrivals,
                            make_arrivals, poisson_arrivals)


def _server(ds, **kw):
    kw.setdefault("cache_frac", 0.3)
    kw.setdefault("seed", 0)
    return SenecaServer.for_dataset(ds, **kw)


# ----------------------------------------------------------------------
# percentile math (exact nearest-rank quantiles)
def test_quantile_exact_on_known_samples():
    xs = list(range(1, 101))            # 1..100
    assert quantile(xs, 0.50) == 50
    assert quantile(xs, 0.99) == 99
    assert quantile(xs, 0.999) == 100
    assert quantile(xs, 1.0) == 100
    assert quantile(xs, 0.0) == 1       # nearest-rank floor: min(ceil)=1


def test_quantile_is_always_an_observed_sample():
    xs = [3.0, 1.0, 4.0, 1.5, 9.0]
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        assert quantile(xs, q) in xs
    assert quantile([7.5], 0.99) == 7.5


def test_quantile_rejects_bad_input():
    with pytest.raises(ValueError):
        quantile([], 0.5)
    with pytest.raises(ValueError):
        quantile([1.0], 1.5)
    with pytest.raises(ValueError):
        quantile([1.0], -0.1)


# ----------------------------------------------------------------------
# arrival schedules
def test_arrivals_seed_reproducible_and_sorted():
    for proc in ("poisson", "bursty", "diurnal"):
        a = make_arrivals(proc, rate=200.0, n=300, seed=5)
        b = make_arrivals(proc, rate=200.0, n=300, seed=5)
        c = make_arrivals(proc, rate=200.0, n=300, seed=6)
        assert np.array_equal(a, b)
        assert not np.array_equal(a, c)
        assert np.all(np.diff(a) >= 0) and a.shape == (300,)


def test_poisson_mean_rate_roughly_right():
    a = poisson_arrivals(100.0, n=5_000, seed=0)
    assert a[-1] == pytest.approx(50.0, rel=0.1)   # n/rate seconds


def test_arrival_validation():
    with pytest.raises(ValueError):
        make_arrivals("weibull", 10.0, 10)
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 10)
    with pytest.raises(ValueError):
        bursty_arrivals(10.0, 10, burst_factor=8.0, duty=0.25)  # >= 1/duty
    with pytest.raises(ValueError):
        diurnal_arrivals(10.0, 10, depth=1.5)


# ----------------------------------------------------------------------
# SLO config
def test_slo_validation():
    SLO(p99_target_s=0.1)
    with pytest.raises(ValueError):
        SLO(p99_target_s=0.0)
    with pytest.raises(ValueError):
        SLO(p99_target_s=0.1, max_queue=0)
    with pytest.raises(ValueError):
        SLO(p99_target_s=0.1, degrade_frac=0.9, encode_frac=0.5)


# ----------------------------------------------------------------------
# telemetry request accounting
def test_record_request_counters_and_summary():
    tel = TelemetryAggregator()
    tel.record_request("shed")
    tel.record_request("served", total_s=0.010,
                       phases={"queue": 0.002, "fetch": 0.008})
    tel.record_request("degraded", total_s=0.030, phases={"queue": 0.030})
    with pytest.raises(ValueError):
        tel.record_request("lost")
    summary = tel.request_summary()
    assert summary["outcomes"] == {"served": 1, "degraded": 1,
                                   "encoded": 0, "shed": 1}
    assert summary["completed"] == 2
    assert summary["latency_s"]["p50"] == 0.010
    assert summary["latency_s"]["p99"] == 0.030
    assert summary["phase_latency_s"]["queue"]["p99"] == 0.030
    # the additive stats key only appears once requests exist
    assert "requests" in tel.as_dict()
    assert "requests" not in TelemetryAggregator().as_dict()


# ----------------------------------------------------------------------
# open-loop serving under VirtualClock
def _run_open_loop(arrivals, slo, *, seed=0, n_samples=96):
    ds = tiny(n=n_samples)
    server = _server(ds)
    clock = VirtualClock()
    storage = RemoteStorage(ds, bandwidth=4e6, clock=clock)
    gen = OpenLoopGenerator(server, storage, clock=clock, slo=slo,
                            n_workers=2, seed=seed,
                            phase_costs={"decode": 0.004,
                                         "augment": 0.003})
    res = gen.run(arrivals)
    stats = server.stats()
    server.close()
    return res, stats


def test_virtual_clock_burst_trace_deterministic_p99():
    arrivals = bursty_arrivals(rate=350.0, n=250, seed=11)
    r1, _ = _run_open_loop(arrivals, None)
    r2, _ = _run_open_loop(arrivals, None)
    lat1 = [(r.req_id, r.total_s, r.queue_s, r.fetch_s, r.decode_s,
             r.augment_s, r.outcome) for r in r1.requests]
    lat2 = [(r.req_id, r.total_s, r.queue_s, r.fetch_s, r.decode_s,
             r.augment_s, r.outcome) for r in r2.requests]
    assert lat1 == lat2                       # per-request, bit-for-bit
    assert r1.percentiles() == r2.percentiles()
    assert r1.percentiles()["p99"] > 0
    # storage stalls flowed through the clock-aware bucket: some fetch
    # phase time must exist even though compute is free in virtual time
    assert any(r.fetch_s > 0 for r in r1.requests)


def test_overload_sheds_instead_of_queueing_unboundedly():
    arrivals = poisson_arrivals(500.0, n=400, seed=3)   # ~1.75x capacity
    slo = SLO(p99_target_s=0.05, max_queue=64)
    uncontrolled, _ = _run_open_loop(arrivals, None)
    controlled, stats = _run_open_loop(arrivals, slo)
    assert uncontrolled.counts["shed"] == 0
    c = controlled.counts
    assert c["shed"] > 0                      # load was actually shed
    assert c["shed"] + c["degraded"] + c["encoded"] + c["served"] == 400
    # the whole point: the tail is held far below the uncontrolled run
    assert controlled.percentiles()["p99"] \
        < uncontrolled.percentiles()["p99"]
    # queue wait (the unbounded-growth signal) is bounded too
    assert max(r.queue_s for r in controlled.completed) \
        < max(r.queue_s for r in uncontrolled.completed)
    # decisions surface in stats(), not just the ServeResult
    req = stats["telemetry"]["requests"]
    assert req["outcomes"]["shed"] == c["shed"]
    assert req["latency_s"]["p99"] > 0


def test_degrade_caps_work_not_cached_quality():
    """A request admitted at encoded level still gets the augmented form
    when the cache already holds it."""
    ds = tiny(n=8)
    server = _server(ds, cache_frac=1.0)
    clock = VirtualClock()
    storage = RemoteStorage(ds, clock=clock)
    # warm every sample to augmented via an uncontrolled pass
    gen = OpenLoopGenerator(server, storage, clock=clock, slo=None,
                            n_workers=1, seed=0)
    warm = gen.run(np.linspace(0.001, 0.02, 16),
                   sample_ids=list(range(8)) * 2)
    assert all(r.outcome == "served" for r in warm.requests)
    # now a fresh generator whose SLO sheds nothing but degrades
    # everything (encode_frac tiny => every queued request degrades)
    gen2 = OpenLoopGenerator(server, storage, clock=VirtualClock(),
                             slo=SLO(p99_target_s=1.0), n_workers=1,
                             seed=0)
    res = gen2.run(np.linspace(0.001, 0.01, 8),
                   sample_ids=list(range(8)))
    # cache hits at augmented form serve full quality regardless of level
    assert all(r.outcome == "served" and r.form == "augmented"
               for r in res.requests)
    server.close()


# ----------------------------------------------------------------------
# satellite bugfix: token bucket paces on the pluggable clock
def test_bandwidth_budget_charges_virtual_time():
    clock = VirtualClock()
    ticket = clock.register()
    clock.bind(ticket)
    try:
        budget = BandwidthBudget(1000.0, clock=clock)
        wall0 = time.monotonic()
        stall = budget.consume(5000)
        assert stall == pytest.approx(5.0)
        assert clock.now() == pytest.approx(5.0)       # virtual seconds
        assert time.monotonic() - wall0 < 1.0          # not wall seconds
        # degrade takes effect at the correct virtual instant: the next
        # transfer is priced at the post-change rate from virtual now
        budget.rate = 100.0
        budget.consume(1000)
        assert clock.now() == pytest.approx(15.0)
    finally:
        clock.unbind()
        clock.unregister(ticket)


def test_bandwidth_budget_wall_clock_default_unchanged():
    budget = BandwidthBudget(1e9)          # no clock: historical behavior
    assert budget.clock is None
    t0 = time.monotonic()
    budget.consume(1000)                   # 1us pacing, returns promptly
    assert time.monotonic() - t0 < 0.5
    assert budget.bytes_served == 1000


def test_remote_storage_degrade_with_virtual_clock():
    ds = tiny(n=16)
    clock = VirtualClock()
    ticket = clock.register()
    clock.bind(ticket)
    try:
        storage = RemoteStorage(ds, bandwidth=1e6, clock=clock)
        storage.fetch(0)
        t_normal = clock.now()
        storage.degrade(0.1)               # 10x slower from this instant
        storage.fetch(1)
        t_degraded = clock.now() - t_normal
        storage.restore_bandwidth()
        assert t_degraded > 5 * t_normal   # collapse shaped virtual time
        assert storage.degraded_fetches == 1
    finally:
        clock.unbind()
        clock.unregister(ticket)


# ----------------------------------------------------------------------
# satellite bugfix: repartition cooldown on the service clock
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def now(self):
        return self.t


def test_repartition_cooldown_uses_service_clock():
    ds = tiny(n=64)
    server = _server(ds, repartition="adaptive",
                     repartition_cooldown=100.0)
    ctl = server.service.controller
    fake = _FakeClock()
    server.service.set_clock(fake)
    ctl.tick()
    first_tick = ctl._last_tick
    assert first_tick == 0.0               # stamped in clock time
    fake.t = 50.0                          # inside the cooldown window
    ctl.tick()
    assert ctl._last_tick == first_tick    # gated, regardless of wall time
    fake.t = 150.0                         # cooldown elapsed (clock time)
    ctl.tick()
    assert ctl._last_tick == 150.0
    server.close()


# ----------------------------------------------------------------------
# satellite bugfix: sub-poll timeouts no longer overshoot
def test_per_sample_get_honors_sub_poll_timeout():
    ds = tiny(n=32)
    server = _server(ds)
    pipe = DSIPipeline(server.open_session(batch_size=8),
                       RemoteStorage(ds))
    try:
        # prefetch never started: the queue stays empty, so get() must
        # raise at ~the 50ms deadline, not after a full 200ms poll
        t0 = time.monotonic()
        with pytest.raises(queue.Empty):
            pipe.get(timeout=0.05)
        assert time.monotonic() - t0 < 0.15
    finally:
        pipe.stop()
        server.close()


class _SlowEncodeDataset(SyntheticDataset):
    """First fetch takes ~0.3s of wall time (stage-parallel pipelines
    cannot emit a batch inside a 50ms get_batch timeout)."""

    def encoded(self, sample_id: int) -> bytes:
        time.sleep(0.3)
        return super().encoded(sample_id)


def test_stage_parallel_get_batch_honors_sub_poll_timeout():
    ds = _SlowEncodeDataset("slow", 32, 24_000, image_hw=(64, 64),
                            crop_hw=(56, 56), n_classes=100)
    server = _server(ds)
    pipe = DSIPipeline(server.open_session(batch_size=8),
                       RemoteStorage(ds), executor="stage-parallel")
    try:
        t0 = time.monotonic()
        with pytest.raises(queue.Empty):
            pipe.get(timeout=0.05)
        assert time.monotonic() - t0 < 0.15
    finally:
        pipe.stop()
        server.close()
