"""Storage-engine tests: tier chains, codecs, spill, FileDataset,
form×tier MDP, residency-aware ODS (ISSUE-5).

Fast, deterministic — tier-1.  The randomized interleaving properties
live in tests/test_cache_properties.py (slow suite).
"""
import os
import threading

import numpy as np
import pytest

from repro.cache.codecs import BytesCodec, NdarrayCodec, codec_for
from repro.cache.store import CachePartition, TieredCache
from repro.cache.tiers import DiskTier, DramTier
from repro.core import mdp
from repro.core.perf_model import (AZURE_NC96, DatasetProfile, GB,
                                   JobProfile, dsi_throughput,
                                   dsi_throughput_tiered)
from repro.data.storage import RemoteStorage
from repro.data.synthetic import FileDataset, tiny


# ----------------------------------------------------------------------
# codecs
def test_codec_for_forms_and_round_trips(tmp_path):
    assert isinstance(codec_for("encoded"), BytesCodec)
    assert isinstance(codec_for("decoded"), NdarrayCodec)
    assert isinstance(codec_for("augmented"), NdarrayCodec)
    with pytest.raises(ValueError):
        codec_for("nope")

    path = str(tmp_path / "x.bin")
    nb, meta = BytesCodec().dump(b"payload", path)
    assert nb == 7 and BytesCodec().load(path, meta) == b"payload"

    arr = np.arange(60, dtype=np.float32).reshape(5, 4, 3)
    nb, meta = NdarrayCodec().dump(arr, path)
    back = NdarrayCodec().load(path, meta)
    assert nb == arr.nbytes and isinstance(back, np.memmap)
    assert np.array_equal(np.asarray(back), arr)
    # empty arrays round-trip without a memmap (memmap rejects size 0)
    empty = np.empty((0, 3), np.uint8)
    nb, meta = NdarrayCodec().dump(empty, path)
    assert np.array_equal(NdarrayCodec().load(path, meta), empty)


# ----------------------------------------------------------------------
# sentinel: falsy / None stored values are hits, not misses
def test_stored_falsy_values_count_as_hits():
    part = CachePartition(1000, "lru")
    part.put(1, b"", 10)
    part.put(2, None, 10)
    assert part.get(1) == b"" and part.stats.misses == 0
    assert part.get(2) is None and part.stats.misses == 0
    assert part.stats.hits == 2
    assert part.get(3) is None and part.stats.misses == 1
    # peek is sentinel-correct too
    assert part.peek(1) == b"" and part.peek(2) is None

    c = TieredCache(3000, (1.0, 0.0, 0.0))
    c.insert(7, "encoded", b"", 10)
    form, value = c.lookup(7)
    assert form == "encoded" and value == b""
    assert c.hit_rate() == 1.0


def test_disk_tier_basics(tmp_path):
    t = DiskTier(1000, str(tmp_path), "encoded")
    assert t.put(1, b"a" * 400, 400) == []
    assert t.put(2, b"b" * 400, 400) == []
    # LRU by default: key 1 is oldest, inserting 3 evicts it
    evicted = t.put(3, b"c" * 400, 400)
    assert [k for k, _v, _nb in evicted] == [1]
    assert 1 not in t and t.get(1) is None        # counted miss
    assert t.get(2) == b"b" * 400                 # served from the stage
    assert t.stats.bytes_used == 800 == sum(
        t.size_of(k) for k in t.keys())
    # write-behind: files appear once the stage is drained (residents
    # only), and reads after the flush come from disk
    t.flush_staged(threading.Lock())
    assert not t._staged
    names = sorted(os.listdir(str(tmp_path / "encoded")))
    assert names == ["2.bin", "3.bin"]
    assert t.get(2) == b"b" * 400
    t.clear()
    assert not os.path.exists(str(tmp_path / "encoded"))


def test_disk_tier_truncated_file_degrades_to_miss(tmp_path):
    """np.memmap raises ValueError (not OSError) when a spill file is
    shorter than dtype*shape — e.g. truncated mid-rewrite by a racing
    writer.  The serving path must treat that as a miss, not crash."""
    t = DiskTier(10_000, str(tmp_path), "decoded")
    arr = np.arange(64, dtype=np.uint8).reshape(8, 8)
    t.put(1, arr, arr.nbytes)
    t.flush_staged(threading.Lock())
    path = os.path.join(str(tmp_path / "decoded"), "1.bin")
    with open(path, "wb") as f:                   # truncate to 1 byte
        f.write(b"\x00")
    assert t.get(1) is None
    assert t.io_errors == 1 and 1 not in t
    # same degradation on the stats-neutral path
    t.put(2, arr, arr.nbytes)
    t.flush_staged(threading.Lock())
    with open(os.path.join(str(tmp_path / "decoded"), "2.bin"),
              "wb") as f:
        f.write(b"\x00")
    assert t.peek(2) is None and t.io_errors == 2
    t.clear()


def test_flush_staged_concurrent_claims_are_exclusive(tmp_path):
    """Two threads draining the stage concurrently must never dump the
    same key's file at once (claim-marking via _inflight): every entry
    ends committed exactly once, index == files on disk, and reads
    serve intact payloads."""
    t = DiskTier(1 << 20, str(tmp_path), "decoded")
    lock = threading.Lock()
    arrs = {k: np.full((16, 16), k, np.uint8) for k in range(24)}
    with lock:
        for k, a in arrs.items():
            t.put(k, a, a.nbytes)
    threads = [threading.Thread(target=t.flush_staged, args=(lock,))
               for _ in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not t._staged and not t._inflight
    names = sorted(os.listdir(str(tmp_path / "decoded")))
    assert names == sorted(f"{k}.bin" for k in arrs)
    for k, a in arrs.items():
        np.testing.assert_array_equal(t.get(k), a)
    assert t.io_errors == 0
    t.clear()


def test_hbm_heat_resets_when_key_leaves_dram():
    """Promotion heat must not survive a key's departure from DRAM: a
    key evicted by a resize and re-admitted later re-earns device
    residency from zero (and the heat map stays bounded by the DRAM
    population instead of growing toward n_total)."""
    from repro.cache.tiers import HbmTier
    hbm = HbmTier(100, "none")
    part = CachePartition(1000, "lru", None, hbm)
    blocker = np.zeros(100, np.uint8)
    part.put(1, blocker, 100)              # fills the device tier
    assert part.tier_of(1) == "hbm"
    a = np.ones(100, np.uint8)
    part.put(2, a, 100)                    # HBM full ("none") -> DRAM
    assert part.tier_of(2) == "dram"
    part.get(2)                            # heat 1 of HBM_PROMOTE_HITS
    part.set_capacity(0)                   # key 2 leaves the chain
    assert part.tier_of(2) is None
    assert 2 not in part._heat, "evicted key kept stale heat"
    part.set_capacity(1000)
    part.put(2, a, 100)                    # re-enters DRAM cold
    hbm.remove(1)                          # device room opens up
    part.get(2)                            # first hit after re-entry...
    assert part.tier_of(2) == "dram", \
        "stale heat promoted a cold re-entrant on its first hit"
    part.get(2)                            # ...the second one earns it
    assert part.tier_of(2) == "hbm"


def test_chain_overflow_and_promotion(tmp_path):
    # "none" DRAM rejects when full -> overflow lands on disk
    spill = DiskTier(5000, str(tmp_path), "encoded")
    part = CachePartition(600, "none", spill)
    assert part.put(1, b"x" * 500, 500) == []
    part.put(2, b"y" * 500, 500)
    assert part.tier_of(1) == "dram" and part.tier_of(2) == "disk"
    # chain lookup: one disk hit; "none" DRAM is full so no promotion
    value, tier = part.get_tiered(2)
    assert value == b"y" * 500 and tier == "disk"
    assert part.tier_of(2) == "disk"
    # lru DRAM promotes and demotes the coldest entry down
    spill2 = DiskTier(5000, str(tmp_path), "decoded")
    lru = CachePartition(600, "lru", spill2)
    a = np.full((10, 10), 1, np.uint8)
    b = np.full((10, 10), 2, np.uint8)
    lru.put(1, a, 500)
    lru.put(2, b, 500)                       # demotes 1 to disk
    assert lru.tier_of(1) == "disk" and lru.demotions == 1
    value, tier = lru.get_tiered(1)          # promotes 1, demotes 2
    assert tier == "disk" and np.array_equal(np.asarray(value), a)
    assert lru.tier_of(1) == "dram" and lru.tier_of(2) == "disk"
    assert lru.promotions == 1 and lru.demotions == 2
    # per-tier ledgers stay exact
    assert lru.dram.stats.bytes_used == 500
    assert lru.spill.stats.bytes_used == 500
    spill.clear(), spill2.clear()


def test_remove_drops_every_tier(tmp_path):
    spill = DiskTier(5000, str(tmp_path), "augmented")
    part = CachePartition(100, "refcount", spill)
    arr = np.ones((4, 4, 3), np.float32)
    part.put(5, arr, arr.nbytes)             # oversized for DRAM -> disk
    assert part.tier_of(5) == "disk"
    assert part.remove(5) and 5 not in part
    assert part.spill.stats.bytes_used == 0
    spill.clear()


# ----------------------------------------------------------------------
# demote -> promote round-trip content equality, all forms, both backends
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_demote_promote_round_trip_all_forms(tmp_path, backend):
    from repro.api import SenecaServer
    ds = tiny(n=32)
    server = SenecaServer.for_dataset(
        ds, cache_bytes=4_000, seed=0, backend=backend, eviction="lru",
        split=(0.34, 0.33, 0.33),
        spill_dir=str(tmp_path / "spill"),
        spill_bytes=10_000_000, spill_split=(0.34, 0.33, 0.33))
    svc = server.service
    originals = {}
    rng = np.random.default_rng(7)
    for k in range(8):
        enc = bytes(rng.integers(0, 256, 600, dtype=np.uint8))
        dec = rng.integers(0, 256, (8, 8, 3)).astype(np.uint8)
        aug = rng.random((6, 6, 3)).astype(np.float32)
        originals[k] = (enc, dec, aug)
        assert svc.admit(k, "encoded", enc, len(enc))
        assert svc.admit(k, "decoded", dec, dec.nbytes)
        assert svc.admit(k, "augmented", aug, aug.nbytes)
    # the lru DRAM tiers hold ~2 entries each; earlier keys are on disk
    demoted = sum(svc.cache.spill_stats()[f]["disk_entries"]
                  for f in ("encoded", "decoded", "augmented"))
    assert demoted > 0
    for k, (enc, dec, aug) in originals.items():
        with svc.cache.lock:
            got_enc = svc.cache.parts["encoded"].peek(k)
            got_dec = svc.cache.parts["decoded"].peek(k)
            got_aug = svc.cache.parts["augmented"].peek(k)
        assert bytes(got_enc) == enc, f"encoded round-trip, key {k}"
        assert np.array_equal(np.asarray(got_dec), dec), \
            f"decoded round-trip, key {k}"
        assert np.array_equal(np.asarray(got_aug), aug), \
            f"augmented round-trip, key {k}"
        # metadata agrees with chain residency (most-processed form)
        assert int(svc.backend.status_of(np.asarray([k]))[0]) == 3
    server.close()
    leftovers = [f for _dp, _dn, fs in os.walk(str(tmp_path / "spill"))
                 for f in fs]
    assert not leftovers


def test_residency_tracks_serving_form_not_best_tier(tmp_path):
    """A sample whose augmented copy spilled to disk serves from disk
    even if its encoded copy is in DRAM — residency_array must report
    the serving form's tier, and form_of must agree without IO."""
    c = TieredCache(2_000, (0.5, 0.0, 0.5),
                    spill_bytes=1_000_000, spill_dir=str(tmp_path),
                    spill_split=(0.5, 0.0, 0.5))
    arr = np.ones((40, 40), np.float32)        # 6.4KB > aug DRAM (1KB)
    assert c.insert(3, "encoded", b"e" * 100, 100)        # DRAM
    assert c.insert(3, "augmented", arr, arr.nbytes)      # disk
    assert c.parts["encoded"].tier_of(3) == "dram"
    assert c.parts["augmented"].tier_of(3) == "disk"
    assert list(c.residency_array(4)) == [0, 0, 0, 1]
    assert c.form_of(3) == "augmented"
    _form, _value, tier = c.lookup_tiered(3)
    assert tier == "disk"                      # what residency promised
    c.close()


def test_version_gate_skips_rebuild_on_unpromoted_disk_hits(tmp_path):
    c = TieredCache(200, (1.0, 0.0, 0.0),
                    spill_bytes=10_000, spill_dir=str(tmp_path),
                    spill_split=(1.0, 0.0, 0.0))
    c.insert(1, "encoded", b"a" * 150, 150)    # DRAM ("none" policy)
    c.insert(2, "encoded", b"b" * 150, 150)    # overflow -> disk
    v = c.version
    # DRAM is full, "none" policy: the disk hit cannot promote, so
    # repeated serves must not bump the version (the O(N) residency
    # rebuild would otherwise run every batch in steady state)
    for _ in range(3):
        assert c.lookup_tiered(2)[2] == "disk"
    assert c.version == v
    assert c.parts["encoded"].promotions == 0
    c.close()


# ----------------------------------------------------------------------
# residency-aware ODS substitution
def test_ods_numpy_prefers_dram_resident_candidates():
    from repro.core.ods import ODSState
    state = ODSState.create(64, seed=1)
    state.register_job(0)
    state.status[:32] = 3                      # cached (augmented)
    residency = np.zeros(64, np.uint8)
    residency[:8] = 2                          # DRAM
    residency[8:32] = 1                        # disk
    state.set_residency(residency)
    requested = np.arange(40, 48)              # all storage misses
    batch, _ = state.sample_batch(0, requested)
    subs = batch[np.isin(batch, np.arange(32))]
    assert len(subs) == 8                      # all slots substituted
    assert set(subs) == set(range(8)), \
        "with 8 DRAM-resident candidates and 8 slots, all picks are DRAM"


def test_ods_jax_tiered_kernel_prefers_dram():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core import ods_jax
    state = ods_jax.create(64)
    state = state._replace(
        status=state.status.at[:32].set(3))
    residency = jnp.zeros(64, jnp.uint8).at[:8].set(2).at[8:32].set(1)
    _state, batch, _em = ods_jax.substitute_tiered_jit(
        state, jnp.arange(40, 48), jax.random.key(0), 5, residency)
    batch = np.asarray(batch)
    assert set(batch) == set(range(8))


# ----------------------------------------------------------------------
# form×tier MDP
def test_form_rates_agree_with_dsi_throughput_per_form():
    """_form_rates is the tiered model's copy of Eqs. 1/3/5/7; it must
    stay numerically identical to dsi_throughput's per-form rates (a
    model fix applied to one but not the other would make solve() and
    solve_tiered() optimize different objectives)."""
    from repro.core.perf_model import _form_rates
    for hw in (AZURE_NC96,):
        for ds in (DatasetProfile("p", 500_000, 120_000.0),
                   DatasetProfile("m", 500_000, 120_000.0,
                                  inflation=5.12)):
            job = JobProfile()
            out = dsi_throughput(hw, ds, job, 0.3, 0.4, 0.3)
            da, dd, de, dsi_s = _form_rates(hw, ds, job, hw.b_cache)
            assert float(out.dsi_a) == pytest.approx(da)
            assert float(out.dsi_d) == pytest.approx(dd)
            assert float(out.dsi_e) == pytest.approx(de)
            assert float(out.dsi_s) == pytest.approx(dsi_s)


def test_jax_tiered_kernel_matches_base_without_residency():
    """The shared-core refactor contract: substitute() and
    substitute_tiered() with an all-DRAM residency rank candidates
    identically, so the two paths can never silently diverge on the
    bookkeeping (rollover, refcount, evict)."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core import ods_jax
    state = ods_jax.create(32)
    state = state._replace(status=state.status.at[:12].set(3))
    key = jax.random.key(3)
    s1, b1, e1 = ods_jax.substitute_jit(state, jnp.arange(20, 28), key, 2)
    s2, b2, e2 = ods_jax.substitute_tiered_jit(
        state, jnp.arange(20, 28), key, 2,
        jnp.full(32, 2, jnp.uint8))        # everything DRAM-resident
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert np.array_equal(np.asarray(e1), np.asarray(e2))
    assert np.array_equal(np.asarray(s1.refcount), np.asarray(s2.refcount))


def test_tiered_model_reduces_to_single_level():
    from dataclasses import replace
    hw = replace(AZURE_NC96, s_cache=40 * GB)
    ds = DatasetProfile("t", 1_000_000, 100_000.0)
    one = dsi_throughput(hw, ds, JobProfile(), 0.2, 0.5, 0.3).overall
    two = dsi_throughput_tiered(hw, ds, JobProfile(), (0.2, 0.5, 0.3),
                                (1.0, 0.0, 0.0))
    assert float(one) == pytest.approx(float(two))


def test_optimize_tiered_beats_dram_only_when_disk_helps():
    from dataclasses import replace
    hw = replace(AZURE_NC96, s_cache=40 * GB)
    ds = DatasetProfile("t", 1_000_000, 100_000.0)
    p0 = mdp.optimize(hw, ds)
    tiered = mdp.optimize_tiered(
        replace(hw, b_disk=2 * GB, s_disk=400 * GB), ds)
    assert tiered.throughput >= p0.throughput
    assert "|" in tiered.label
    # no disk -> degenerate, same split and throughput as one-level
    t0 = mdp.optimize_tiered(hw, ds)
    assert t0.dram.label == p0.label
    assert t0.throughput == pytest.approx(p0.throughput)


def test_apply_partition_resizes_both_levels(tmp_path):
    from repro.api import SenecaServer
    ds = tiny(n=64)
    server = SenecaServer.for_dataset(
        ds, cache_bytes=10_000, seed=0, split=(0.5, 0.5, 0.0),
        spill_dir=str(tmp_path), spill_bytes=20_000,
        spill_split=(0.5, 0.5, 0.0))
    svc = server.service
    svc.apply_partition(mdp.Partition(0.2, 0.8, 0.0, float("nan")),
                        mdp.Partition(0.1, 0.9, 0.0, float("nan")))
    assert svc.cache.parts["encoded"].capacity == 2_000
    assert svc.cache.parts["decoded"].capacity == 8_000
    assert svc.cache.parts["encoded"].spill.capacity == 2_000
    assert svc.cache.parts["decoded"].spill.capacity == 18_000
    assert svc.disk_partition.label == "10-90-0"
    server.close()


def test_spill_resize_demotes_and_patches_metadata(tmp_path):
    from repro.api import SenecaServer
    ds = tiny(n=64)
    server = SenecaServer.for_dataset(
        ds, cache_bytes=4_000, seed=0, split=(1.0, 0.0, 0.0),
        spill_dir=str(tmp_path), spill_bytes=4_000,
        spill_split=(1.0, 0.0, 0.0))
    svc = server.service
    for k in range(4):
        assert svc.admit(k, "encoded", bytes([k]) * 900, 900)
    # 4 x 900B: ~4 fit in DRAM; shrink DRAM to force demotions to disk
    svc.apply_partition(mdp.Partition(0.25, 0.5, 0.25, float("nan")))
    part = svc.cache.parts["encoded"]
    assert len(part.dram) + len(part.spill) <= 4
    status = svc.backend.status_of(np.arange(4))
    with svc.cache.lock:
        for k in range(4):
            if status[k] == 1:
                assert k in part      # metadata never overstates
    server.close()


# ----------------------------------------------------------------------
# FileDataset
def test_file_dataset_matches_synthetic_and_reuses_shards(tmp_path):
    ds = tiny(n=48)
    root = str(tmp_path / "shards")
    fd = FileDataset(ds, root, shard_bytes=128 * 1024)
    assert fd.n_shards > 1
    for i in (0, 7, 47):
        assert fd.encoded(i) == ds.encoded(i)
        assert fd.encoded_size(i) == ds.encoded_size(i)
        assert fd.label(i) == ds.label(i)
    assert np.array_equal(fd.decode(fd.encoded(3), 3),
                          ds.decode(ds.encoded(3), 3))
    # second construction reuses the on-disk shards
    before = sorted(os.listdir(root))
    fd2 = FileDataset(ds, root)
    assert sorted(os.listdir(root)) == before
    assert fd2.encoded(11) == ds.encoded(11)
    # a different dataset must not silently read the wrong shards
    with pytest.raises(ValueError):
        FileDataset(tiny(n=16), root)
    fd2.remove_files()
    assert not os.path.exists(root)


def test_file_dataset_through_remote_storage_budget(tmp_path):
    ds = tiny(n=16)
    fd = FileDataset(ds, str(tmp_path / "s"))
    storage = RemoteStorage(fd, bandwidth=None)
    assert storage.fetch(3) == ds.encoded(3)
    assert storage.fetches == 1
    assert storage.budget.bytes_served == len(ds.encoded(3))


# ----------------------------------------------------------------------
# atomic counters under multi-threaded fetch
def test_storage_counters_are_atomic_under_threads():
    ds = tiny(n=64)
    storage = RemoteStorage(ds, bandwidth=None)
    n_threads, per = 8, 50

    def worker(tid):
        for i in range(per):
            storage.fetch((tid * per + i) % ds.n_samples)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert storage.fetches == n_threads * per
    expect = sum(len(ds.encoded((t * per + i) % ds.n_samples))
                 for t in range(n_threads) for i in range(per))
    assert storage.budget.bytes_served == expect


# ----------------------------------------------------------------------
# end-to-end: live pipeline over a spill-backed server, then clean close
def test_pipeline_over_spill_server_serves_disk_hits(tmp_path):
    from repro.api import SenecaServer
    from repro.data.pipeline import DSIPipeline
    ds = tiny(n=128)
    server = SenecaServer.for_dataset(
        ds, cache_frac=0.04, seed=0, split=(0.2, 0.8, 0.0),
        spill_dir=str(tmp_path / "spill"),
        spill_bytes=int(0.9 * ds.n_samples * ds.augmented_bytes()),
        spill_split=(0.35, 0.65, 0.0))
    storage = RemoteStorage(ds)
    pipe = DSIPipeline(server.open_session(batch_size=16), storage,
                       n_workers=2)
    for _ in range(2 * (ds.n_samples // 16)):     # two epochs
        pipe.next_batch()
    stats = server.stats()
    assert stats["residency_counts"]["disk"] > 0
    assert sum(s["disk_hits"] for s in stats["spill"].values()) > 0
    assert stats["telemetry"]["b_disk"] is not None
    pipe.stop()
    server.close()
    leftovers = [f for _dp, _dn, fs in os.walk(str(tmp_path / "spill"))
                 for f in fs]
    assert not leftovers, leftovers


# ----------------------------------------------------------------------
# HBM tier: three-level model, ODS preference, live three-level resize
def test_tiered_model_hbm_zero_split_is_byte_identical():
    """Regression pin: with no device tier configured (s_hbm == 0) the
    three-level model must be *bit-identical* to the two-level one —
    passing an hbm_split may not perturb a single float in the
    reduction (the hbm coverage term must stay an exact 0.0 scalar, not
    an array that re-associates the sums)."""
    from dataclasses import replace
    hw = replace(AZURE_NC96, s_cache=40 * GB, b_disk=2 * GB,
                 s_disk=400 * GB)
    ds = DatasetProfile("t", 1_000_000, 100_000.0)
    job = JobProfile()
    for dram in [(0.2, 0.5, 0.3), (1.0, 0.0, 0.0), (0.0, 0.0, 1.0)]:
        for disk in [(1.0, 0.0, 0.0), (0.3, 0.3, 0.4)]:
            base = dsi_throughput_tiered(hw, ds, job, dram, disk)
            for hbm in [None, (0.2, 0.5, 0.3), (0.0, 0.0, 1.0)]:
                got = dsi_throughput_tiered(hw, ds, job, dram, disk,
                                            hbm_split=hbm)
                assert float(got) == float(base), (dram, disk, hbm)


def test_optimize_tiered_three_level():
    from dataclasses import replace
    hw2 = replace(AZURE_NC96, s_cache=40 * GB, b_disk=2 * GB,
                  s_disk=400 * GB)
    hw3 = replace(hw2, b_hbm=100 * GB, s_hbm=8 * GB)
    ds = DatasetProfile("t", 1_000_000, 100_000.0)
    two = mdp.optimize_tiered(hw2, ds)
    three = mdp.optimize_tiered(hw3, ds)
    assert two.hbm is None
    assert three.hbm is not None
    assert three.label.count("|") == 2          # hbm|dram|disk
    assert three.throughput >= two.throughput
    # the solved hbm split is a valid simplex point
    s = three.hbm.x_e + three.hbm.x_d + three.hbm.x_a
    assert s == pytest.approx(1.0)


def test_ods_numpy_prefers_hbm_resident_candidates():
    from repro.core.ods import ODSState
    state = ODSState.create(64, seed=1)
    state.register_job(0)
    state.status[:32] = 3                      # cached (augmented)
    residency = np.zeros(64, np.uint8)
    residency[:8] = 3                          # HBM (device-resident)
    residency[8:16] = 2                        # DRAM
    residency[16:32] = 1                       # disk
    state.set_residency(residency)
    requested = np.arange(40, 48)              # all storage misses
    batch, _ = state.sample_batch(0, requested)
    subs = batch[np.isin(batch, np.arange(32))]
    assert len(subs) == 8
    assert set(subs) == set(range(8)), \
        "with 8 HBM-resident candidates and 8 slots, all picks are HBM"


def test_ods_jax_tiered_kernel_prefers_hbm():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core import ods_jax
    state = ods_jax.create(64)
    state = state._replace(status=state.status.at[:32].set(3))
    residency = (jnp.zeros(64, jnp.uint8).at[:8].set(3)
                 .at[8:16].set(2).at[16:32].set(1))
    _state, batch, _em = ods_jax.substitute_tiered_jit(
        state, jnp.arange(40, 48), jax.random.key(0), 5, residency)
    assert set(np.asarray(batch)) == set(range(8))


def test_apply_partition_resizes_three_levels():
    from repro.api import SenecaServer
    ds = tiny(n=64)
    server = SenecaServer.for_dataset(
        ds, cache_bytes=10_000, seed=0, split=(0.5, 0.5, 0.0),
        device_cache_bytes=6_000, hbm_split=(0.0, 0.5, 0.5))
    svc = server.service
    assert svc.has_hbm
    assert svc.cache.parts["decoded"].hbm.capacity == 3_000
    svc.apply_partition(mdp.Partition(0.2, 0.8, 0.0, float("nan")),
                        None,
                        mdp.Partition(0.0, 0.0, 1.0, float("nan")))
    assert svc.cache.parts["encoded"].capacity == 2_000
    assert svc.cache.parts["decoded"].capacity == 8_000
    assert svc.cache.parts["decoded"].hbm.capacity == 0
    assert svc.cache.parts["augmented"].hbm.capacity == 6_000
    assert svc.hbm_partition.label == "0-0-100"
    assert "hbm" in server.stats()["residency_counts"] or \
        server.stats()["residency_counts"]["storage"] == 64
    server.close()


def test_h2d_telemetry_calibrates_b_hbm():
    from repro.api.telemetry import TelemetryAggregator
    from repro.core.perf_model import calibrate
    tel = TelemetryAggregator()
    for _ in range(8):
        tel.record_bytes("h2d", 1_000_000, 0.001)   # 1 GB/s observed
    snap = tel.snapshot()
    assert snap.b_hbm == pytest.approx(1e9)
    assert snap.counts["b_hbm"] == 8
    hw = calibrate(AZURE_NC96, snap, min_samples=8)
    assert hw.b_hbm == pytest.approx(1e9)
    assert hw.name.endswith("+calibrated")
