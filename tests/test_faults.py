"""Fault-injection + failover layer (ISSUE-8).

Covers the acceptance trace — a VirtualClock workload surviving a shard
kill, spill-file corruption, and a job preemption with exactly-once-per-
epoch coverage and byte-for-byte determinism across two runs — plus the
per-domain fault paths: shard failover + ring re-expansion on restart,
sampler checkpoint/restore through ``Session``, storage bandwidth
collapse, worker-crash recovery, and the :class:`FaultSpec` /
:class:`LivenessRegistry` contracts.
"""
import numpy as np
import pytest

from repro.api import (FaultSpec, JobSpec, SenecaServer, VirtualClock,
                       WorkloadRunner)
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.faults import FAULT_KINDS, FaultInjector, LivenessRegistry
from repro.faults.injector import corrupt_spill_files


def _server(ds, **kw):
    kw.setdefault("cache_frac", 0.4)
    kw.setdefault("seed", 0)
    return SenecaServer.for_dataset(ds, **kw)


def _coverage_exact(sample_ids, n):
    ids = np.asarray(sample_ids)
    if len(ids) % n:
        return False
    want = np.arange(n)
    return all(np.array_equal(np.sort(ids[e * n:(e + 1) * n]), want)
               for e in range(len(ids) // n))


# ----------------------------------------------------------------------
# FaultSpec validation
def test_fault_spec_kinds_and_validation():
    assert "shard-kill" in FAULT_KINDS and "preempt" in FAULT_KINDS
    with pytest.raises(ValueError):
        FaultSpec("no-such-kind", at_s=0.0)
    with pytest.raises(ValueError):
        FaultSpec("preempt", at_s=0.1)            # job required
    with pytest.raises(ValueError):
        FaultSpec("worker-crash", at_s=0.1)       # job required
    with pytest.raises(ValueError):
        FaultSpec("shard-kill", at_s=0.1)         # shard required
    with pytest.raises(ValueError):
        FaultSpec("bandwidth-collapse", at_s=0.1, factor=0.0)
    with pytest.raises(ValueError):
        FaultSpec("spill-corrupt", at_s=0.1, n_files=0)
    with pytest.raises(ValueError):
        FaultSpec("preempt", at_s=-1.0, job="a")
    s = FaultSpec("shard-kill", at_s=0.5, shard=1, duration_s=0.2)
    assert (s.kind, s.at_s, s.shard, s.duration_s) == \
        ("shard-kill", 0.5, 1, 0.2)


def test_injector_requires_targets():
    with pytest.raises(ValueError, match="server"):
        FaultInjector([FaultSpec("shard-kill", at_s=0.0, shard=0)])
    with pytest.raises(ValueError, match="RemoteStorage"):
        FaultInjector([FaultSpec("bandwidth-collapse", at_s=0.0)],
                      server=object())


# ----------------------------------------------------------------------
# LivenessRegistry
def test_liveness_registry_expiry_and_overrides():
    t = [0.0]

    class FakeClock:
        def now(self):
            return t[0]

    reg = LivenessRegistry(dead_after_s=5.0, clock=FakeClock())
    reg.beat("h0")
    reg.beat("h1")
    assert reg.failed() == []
    t[0] = 6.0
    assert sorted(reg.failed()) == ["h0", "h1"]
    # expiry means "maybe slow" — is_dead() reports explicit marks only
    assert not reg.is_dead("h0")
    reg.beat("h0")
    assert reg.failed() == ["h1"]
    reg.mark_dead("h0")                  # explicit kill beats heartbeats
    assert reg.is_dead("h0")
    reg.mark_alive("h0")
    assert not reg.is_dead("h0") and reg.failed() == ["h1"]
    reg.forget("h1")
    assert reg.failed() == []


# ----------------------------------------------------------------------
# Shard failover + ring re-expansion
def test_shard_kill_failover_and_restart(tmp_path):
    ds = tiny(n=96)
    server = _server(ds, shards=2)
    try:
        svc = server.service
        cache = svc.cache
        n = ds.n_samples
        owned = np.flatnonzero(
            cache.router.shard_of_many(np.arange(n)) == 1)
        assert len(owned) > 0
        data = np.zeros(64, np.uint8)
        cache.insert(int(owned[0]), "decoded", data, data.nbytes)
        assert cache.lookup_tiered(int(owned[0]))[0] == "decoded"

        svc.fail_shard(1)
        # dead shard degrades: lookups miss, inserts are dropped, the
        # failover counter moves, and stats carry the dead marker
        assert cache.lookup_tiered(int(owned[0]))[0] is None
        assert cache.insert(int(owned[1]), "decoded", data,
                            data.nbytes) is False
        assert cache.failovers > 0
        dead = [s for s in cache.shard_stats() if s.get("dead")]
        assert [s["shard"] for s in dead] == [1]
        # surviving shard still serves its own keys
        other = np.flatnonzero(
            cache.router.shard_of_many(np.arange(n)) == 0)
        assert cache.insert(int(other[0]), "decoded", data, data.nbytes)
        assert cache.lookup_tiered(int(other[0]))[0] == "decoded"
        # the dead shard's keys now read as storage-resident, not cached
        res = cache.residency_array(n)
        assert not res[owned].any()

        v_dead = cache.version
        svc.restore_shard(1)
        assert cache.version != v_dead     # generation bump, no masking
        assert not any(s.get("dead") for s in cache.shard_stats())
        assert cache.insert(int(owned[2]), "decoded", data, data.nbytes)
        assert cache.lookup_tiered(int(owned[2]))[0] == "decoded"
        stats = svc.stats()
        assert stats["faults"]["counts"]["fault.shard-kill"] == 1
        assert stats["faults"]["counts"]["recovery.shard-restart"] == 1
        assert stats["faults"]["shard_failovers"] > 0
    finally:
        server.close()


# ----------------------------------------------------------------------
# Session sampler checkpoint/restore
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_session_checkpoint_restore_roundtrip(backend):
    ds = tiny(n=64)
    server = _server(ds, backend=backend)
    try:
        sess = server.open_session(batch_size=8)
        pre = [sess.next_batch_ids()[0] for _ in range(3)]
        snap = sess.checkpoint_state()
        assert snap["format"] == 1
        cont = [sess.next_batch_ids()[0] for _ in range(5)]
        sess.close()

        sess2 = server.open_session(batch_size=8)
        sess2.restore_state(snap)
        resumed = [sess2.next_batch_ids()[0] for _ in range(5)]
        if backend == "numpy":
            # restored session replays the exact post-checkpoint stream
            assert [list(b) for b in resumed] == [list(b) for b in cont]
        # exactly-once-per-epoch coverage holds for checkpoint + resume
        # on both backends (the jax backend's substitution RNG key is
        # shared and deliberately not restored, so its post-restore
        # *order* may differ — coverage may not)
        ids = [i for b in pre + resumed for i in b]
        assert _coverage_exact(ids, 64)
        sess2.close()
    finally:
        server.close()


def test_session_restore_rejects_mismatched_shape():
    ds = tiny(n=64)
    server = _server(ds)
    try:
        sess = server.open_session(batch_size=8)
        snap = sess.checkpoint_state()
        other = server.open_session(batch_size=16)
        with pytest.raises(ValueError):
            other.restore_state(snap)       # batch_size mismatch
        with pytest.raises(ValueError):
            sess.restore_state({**snap, "format": 99})
        sess.close()
        other.close()
    finally:
        server.close()


# ----------------------------------------------------------------------
# Storage bandwidth collapse
def test_storage_degrade_and_restore():
    ds = tiny(n=16)
    storage = RemoteStorage(ds, bandwidth=1e9)
    storage.fetch(0)
    assert storage.degraded_fetches == 0
    storage.degrade(0.5)
    assert storage.degraded and storage.budget.rate == 0.5e9
    storage.fetch(1)
    assert storage.degraded_fetches == 1
    storage.restore_bandwidth()
    assert not storage.degraded and storage.budget.rate == 1e9
    with pytest.raises(ValueError):
        storage.degrade(0.0)
    # unlimited store: flag flips but there is no rate to scale
    unl = RemoteStorage(ds)
    unl.degrade(0.1)
    assert unl.degraded and unl.budget.rate is None


# ----------------------------------------------------------------------
# Spill corruption helper
def test_corrupt_spill_files_truncates_deterministically(tmp_path):
    for name in ("b.bin", "a.bin", "c.bin"):
        (tmp_path / name).write_bytes(b"x" * 64)
    hit = corrupt_spill_files(str(tmp_path), 2)
    assert [p.rsplit("/", 1)[1] for p in hit] == ["a.bin", "b.bin"]
    assert (tmp_path / "a.bin").stat().st_size == 1
    assert (tmp_path / "c.bin").stat().st_size == 64


# ----------------------------------------------------------------------
# End-to-end acceptance trace: shard kill + spill corruption + preempt
def _acceptance_run(policy, tmp_path, seed=0, tag="r"):
    ds = tiny(n=128)
    spill = tmp_path / f"spill-{tag}"
    spill.mkdir()
    server = _server(
        ds, shards=2, cache_frac=0.3, spill_dir=str(spill),
        spill_bytes=int(0.2 * 128 * ds.augmented_bytes()))
    storage = RemoteStorage(ds)
    faults = [
        FaultSpec("shard-kill", at_s=0.05, shard=1, duration_s=0.1),
        FaultSpec("spill-corrupt", at_s=0.08, n_files=2),
        FaultSpec("preempt", at_s=0.10, job="a", duration_s=0.06),
    ]
    runner = WorkloadRunner(server, storage, clock=VirtualClock(),
                            seed=seed, faults=faults, fault_policy=policy)
    res = runner.run([
        JobSpec("a", arrival_s=0.0, epochs=2, batch_size=16,
                gpu_rate=1000),
        JobSpec("b", arrival_s=0.02, epochs=2, batch_size=16,
                gpu_rate=700),
    ], timeout=300)
    stats = res.stats
    server.close()
    return res, stats


def test_acceptance_trace_coverage_and_determinism(tmp_path):
    r1, stats = _acceptance_run("checkpoint", tmp_path, tag="r1")
    r2, _ = _acceptance_run("checkpoint", tmp_path, tag="r2")
    # byte-for-byte reproducible under the VirtualClock
    assert r1.makespan == r2.makespan
    for a, b in zip(r1.jobs, r2.jobs):
        assert a.sample_ids == b.sample_ids
        assert a.epoch_ends == b.epoch_ends
    # exactly-once-per-epoch coverage survives all three fault kinds
    for job in r1.jobs:
        assert _coverage_exact(job.sample_ids, 128), job.spec.name
    assert sum(j.preemptions for j in r1.jobs) == 1
    counts = stats["faults"]["counts"]
    assert counts["fault.shard-kill"] == 1
    assert counts["fault.spill-corrupt"] == 1
    assert counts["fault.preempt"] == 1
    assert counts["recovery.shard-restart"] == 1
    assert counts["recovery.preempt-readmit"] == 1
    assert stats["faults"]["injected"] >= 3
    assert stats["faults"]["recovered"] >= 2


def test_naive_restart_replays_but_still_covers(tmp_path):
    rec, _ = _acceptance_run("checkpoint", tmp_path, tag="c")
    naive, _ = _acceptance_run("restart", tmp_path, tag="n")
    for job in naive.jobs:
        assert _coverage_exact(job.sample_ids, 128), job.spec.name
    a_rec = next(j for j in rec.jobs if j.spec.name == "a")
    a_naive = next(j for j in naive.jobs if j.spec.name == "a")
    # restart resets the job's counters, so the replayed progress shows
    # up as extra runtime, not extra recorded samples
    assert a_naive.samples == a_rec.samples
    assert a_naive.duration_s > a_rec.duration_s


def test_worker_crash_recovery(tmp_path):
    ds = tiny(n=64)
    server = _server(ds)
    storage = RemoteStorage(ds)
    runner = WorkloadRunner(
        server, storage, clock=VirtualClock(), seed=0,
        faults=[FaultSpec("worker-crash", at_s=0.03, job="a")])
    res = runner.run([JobSpec("a", arrival_s=0.0, epochs=2,
                              batch_size=8, gpu_rate=1000)], timeout=300)
    server.close()
    job = res.jobs[0]
    assert job.worker_restarts == 1
    assert _coverage_exact(job.sample_ids, 64)


def test_unknown_fault_job_rejected():
    ds = tiny(n=32)
    server = _server(ds)
    storage = RemoteStorage(ds)
    runner = WorkloadRunner(
        server, storage, clock=VirtualClock(), seed=0,
        faults=[FaultSpec("preempt", at_s=0.1, job="ghost",
                          duration_s=0.1)])
    with pytest.raises(ValueError, match="ghost"):
        runner.run([JobSpec("a", arrival_s=0.0, epochs=1, batch_size=8,
                            gpu_rate=1000)], timeout=60)
    server.close()


def test_shard_fault_needs_sharded_server():
    ds = tiny(n=32)
    server = _server(ds)          # shards=1: single-process cache
    storage = RemoteStorage(ds)
    runner = WorkloadRunner(
        server, storage, clock=VirtualClock(), seed=0,
        faults=[FaultSpec("shard-kill", at_s=0.1, shard=0)])
    with pytest.raises(ValueError, match="shard"):
        runner.run([JobSpec("a", arrival_s=0.0, epochs=1, batch_size=8,
                            gpu_rate=1000)], timeout=60)
    server.close()


def test_bad_fault_policy_rejected():
    ds = tiny(n=32)
    server = _server(ds)
    with pytest.raises(ValueError):
        WorkloadRunner(server, RemoteStorage(ds), clock=VirtualClock(),
                       fault_policy="yolo")
    server.close()
