"""Live cache repartitioning: TieredCache.resize, telemetry calibration,
and RepartitionController hysteresis."""
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import SenecaConfig, SenecaServer
from repro.api.policies import CapacityAdmission
from repro.api.telemetry import TelemetryAggregator
from repro.cache.store import CachePartition, TieredCache
from repro.core import mdp
from repro.core.perf_model import AZURE_NC96, IMAGENET_1K, calibrate


# ----------------------------------------------------------------------
# CachePartition.peek / set_capacity
def test_peek_is_stats_neutral():
    part = CachePartition(100, "lru")
    part.put(1, "a", 10)
    part.put(2, "b", 10)
    before = (part.stats.hits, part.stats.misses)
    assert part.peek(1) == "a"
    assert part.peek(99) is None
    assert (part.stats.hits, part.stats.misses) == before
    # no LRU promotion either: 1 is still the eviction candidate
    part.set_capacity(10)
    assert 1 not in part and 2 in part


def test_tiered_peek_stats_neutral_and_ordered():
    c = TieredCache(3000, (0.34, 0.33, 0.33))
    c.insert(7, "encoded", b"e", 10)
    c.insert(7, "augmented", b"a", 10)
    assert c.peek(7) == ("augmented", b"a")
    assert c.peek(8) == (None, None)
    assert c.lookup_misses == 0
    assert c.hit_rate() == 0.0


def test_shrink_below_usage_respects_lru_order():
    part = CachePartition(100, "lru")
    for k in (1, 2, 3, 4):
        part.put(k, "v", 25)
    part.get(1)                       # 1 becomes MRU
    evicted = part.set_capacity(50)
    assert evicted == [2, 3]          # LRU order, 1 survives
    assert 1 in part and 4 in part
    assert part.stats.bytes_used == 50


def test_shrink_below_usage_fifo_for_no_evict_policy():
    part = CachePartition(100, "none")
    for k in (5, 6, 7, 8):
        part.put(k, "v", 25)
    evicted = part.set_capacity(30)
    assert evicted == [5, 6, 7]       # insertion order
    assert part.stats.bytes_used == 25 and 8 in part


@settings(max_examples=25, deadline=None)
@given(cap=st.integers(200, 5_000),
       ops=st.lists(st.tuples(st.integers(0, 40), st.integers(1, 800)),
                    min_size=1, max_size=50),
       new_cap=st.integers(0, 2_000),
       policy=st.sampled_from(["none", "lru", "refcount"]))
def test_set_capacity_byte_accounting_exact(cap, ops, new_cap, policy):
    part = CachePartition(cap, policy)
    for key, size in ops:
        part.put(key, b"x", size)
    evicted = part.set_capacity(new_cap)
    assert part.stats.bytes_used == sum(part._sizes.values())
    assert part.stats.bytes_used <= new_cap or not part._sizes
    assert len(set(evicted)) == len(evicted)
    for k in evicted:
        assert k not in part


def test_resize_grow_then_shrink_round_trip():
    c = TieredCache(3000, (0.4, 0.3, 0.3))
    caps0 = {f: c.parts[f].capacity for f in c.parts}
    c.insert(1, "encoded", b"e", 100)
    c.insert(2, "decoded", b"d", 100)
    assert c.resize((0.1, 0.1, 0.8)) == {}        # everything still fits
    assert c.parts["augmented"].capacity == 2400
    assert c.resize((0.4, 0.3, 0.3)) == {}
    assert {f: c.parts[f].capacity for f in c.parts} == caps0
    assert c.peek(1) == ("encoded", b"e")
    assert c.peek(2) == ("decoded", b"d")
    assert c.split == (0.4, 0.3, 0.3)


def test_resize_shrink_evicts_and_reports_by_form():
    c = TieredCache(300, (1 / 3, 1 / 3, 1 / 3))
    for k in range(4):
        assert c.insert(k, "decoded", b"d", 25)
    evicted = c.resize((0.5, 0.0, 0.5))
    assert sorted(evicted["decoded"]) == [0, 1, 2, 3]
    assert c.parts["decoded"].capacity == 0
    assert c.bytes_used() == 0
    # instantaneous capacity sum never exceeded the total (shrink-first
    # ordering): growing tiers land at their exact targets
    assert c.parts["encoded"].capacity == 150
    assert c.parts["augmented"].capacity == 150


def test_resize_no_deadlock_under_concurrent_insert_gated():
    c = TieredCache(10_000, (0.4, 0.3, 0.3))
    policy = CapacityAdmission()
    stop = threading.Event()
    errors = []

    def hammer(tid):
        try:
            k = tid * 10_000
            while not stop.is_set():
                k += 1
                c.insert_gated(k % 500, "decoded", b"v", 37, policy)
                c.lookup(k % 500)
        except Exception as e:                    # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    splits = [(0.4, 0.3, 0.3), (0.1, 0.8, 0.1), (0.8, 0.1, 0.1),
              (0.0, 0.0, 1.0), (1 / 3, 1 / 3, 1 / 3)]
    for _ in range(20):
        for s in splits:
            c.resize(s)
    stop.set()
    for t in threads:
        t.join(timeout=10.0)
        assert not t.is_alive(), "deadlock: worker never finished"
    assert not errors
    for form, part in c.parts.items():
        with c.lock:
            assert part.stats.bytes_used == sum(part._sizes.values()), form
            assert part.stats.bytes_used <= part.capacity or not part._sizes


# ----------------------------------------------------------------------
# telemetry -> calibrate
def test_snapshot_rates_and_counts():
    tel = TelemetryAggregator()
    tel.add_concurrency(4)
    for _ in range(8):
        tel.record_stage("decode", 0.02)
        tel.record_stage("augment", 0.005)
        tel.record_bytes("storage", 1_000_000, 0.01)
    snap = tel.snapshot()
    assert snap.t_a == pytest.approx(4 / 0.005)
    assert snap.t_da == pytest.approx(4 / 0.025)
    assert snap.b_storage == pytest.approx(1e8)
    assert snap.counts == {"t_da": 8, "t_a": 8, "b_storage": 8,
                           "b_cache": 0, "b_disk": 0, "b_hbm": 0}
    tel.record_serve("augmented")
    tel.record_serve(None)
    rates = tel.snapshot().hit_rates()
    assert rates["augmented"] == 0.5 and rates["storage"] == 0.5


def test_calibrate_respects_min_samples_and_is_identity_when_cold():
    tel = TelemetryAggregator()
    snap = tel.snapshot()
    assert calibrate(AZURE_NC96, snap) is AZURE_NC96     # no signal at all
    for _ in range(4):
        tel.record_stage("decode", 0.01)
        tel.record_stage("augment", 0.01)
    assert calibrate(AZURE_NC96, tel.snapshot(),
                     min_samples=8) is AZURE_NC96        # below the floor
    hw = calibrate(AZURE_NC96, tel.snapshot(), min_samples=4)
    assert hw.t_da == pytest.approx(1 / 0.02)
    assert hw.t_a == pytest.approx(1 / 0.01)
    assert hw.b_storage == AZURE_NC96.b_storage          # never observed
    assert hw.name == "azure-nc96ads+calibrated"
    # re-calibrating a calibrated profile doesn't stack name suffixes
    assert calibrate(hw, tel.snapshot(), min_samples=4).name == hw.name


def test_incremental_solver_matches_optimize():
    solver = mdp.IncrementalSolver(IMAGENET_1K, step=0.02)
    ref = mdp.optimize(AZURE_NC96, IMAGENET_1K, step=0.02)
    got = solver.solve(AZURE_NC96)
    assert (got.x_e, got.x_d, got.x_a) == (ref.x_e, ref.x_d, ref.x_a)
    assert got.throughput == pytest.approx(ref.throughput)
    assert solver.predict(AZURE_NC96, (got.x_e, got.x_d, got.x_a)) == \
        pytest.approx(got.throughput)


# ----------------------------------------------------------------------
# controller hysteresis
def _server(**kw):
    cfg = SenecaConfig(cache_bytes=int(4e9), hardware=AZURE_NC96,
                       dataset=IMAGENET_1K, **kw)
    return SenecaServer(cfg)


def _feed_slow_cpu(server, n=16):
    tel = server.service.telemetry
    tel.add_concurrency(4)
    for _ in range(n):
        tel.record_stage("decode", 0.01)
        tel.record_stage("augment", 0.004)
        tel.record_bytes("storage", 100_000, 0.001)


def test_static_mode_never_repartitions():
    server = _server()                      # repartition defaults "static"
    split0 = server.partition
    with server.open_session(batch_size=8):
        pass
    _feed_slow_cpu(server)
    assert server.maybe_repartition() is False
    ctl = server.service.controller
    assert (ctl.resolves, ctl.applied) == (0, 0)
    assert server.partition is split0
    server.close()


def test_adaptive_applies_once_then_no_churn():
    server = _server(repartition="adaptive", repartition_cooldown=0.0,
                     telemetry_min_samples=8)
    _feed_slow_cpu(server)
    assert server.maybe_repartition() is True
    ctl = server.service.controller
    applied_after_first = ctl.applied
    resolves_after_first = ctl.resolves
    for _ in range(6):                      # steady telemetry: all no-ops
        assert server.maybe_repartition() is False
    assert ctl.applied == applied_after_first
    assert ctl.resolves == resolves_after_first
    rp = server.stats()["repartitions"]
    assert rp["applied"] == 1 and rp["last"]["applied"] is True
    server.close()


def test_on_change_resolves_on_session_churn_but_apply_is_gated():
    server = _server(repartition="on-change")
    ctl = server.service.controller
    with server.open_session(batch_size=8):
        assert ctl.resolves == 1
    assert ctl.resolves == 2                # close re-solved too
    # no telemetry -> identical profile -> same split -> nothing applied
    assert ctl.applied == 0 and ctl.skipped == 2
    # explicit ticks are an adaptive-only path
    assert server.maybe_repartition() is False
    assert ctl.resolves == 2
    server.close()


def test_apply_demotes_ods_metadata():
    server = _server(split=(0.0, 1.0, 0.0), repartition="adaptive")
    svc = server.service
    ids = np.arange(4)
    for i in ids:
        assert svc.cache.insert(int(i), "decoded", b"d" * 8, 8)
    svc.backend.mark_cached(ids, 2)         # DECODED
    demoted = svc.apply_partition(
        mdp.Partition(0.5, 0.0, 0.5, throughput=1.0))
    assert demoted == {"storage": 4}
    assert (svc.backend.status_of(ids) == 0).all()
    assert server.partition.label == "50-0-50"
    server.close()


def test_stats_keys_are_additive():
    server = _server()
    stats = server.stats()
    for key in ("partition", "predicted_throughput", "ods_hit_rate",
                "cache_lookup_hit_rate", "tier_counts", "metadata_bytes"):
        assert key in stats                 # pre-existing surface intact
    assert stats["repartitions"]["mode"] == "static"
    assert "telemetry" in stats
    server.close()


def test_unknown_repartition_mode_rejected():
    with pytest.raises(ValueError, match="repartition"):
        _server(repartition="sometimes")
