"""ODS invariants (Seneca §5.2) — the properties the paper guarantees."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ods import (AUGMENTED, DECODED, ENCODED, IN_STORAGE,
                            EpochSampler, ODSState)


def _drive(n, batch, jobs, cached_frac, steps, form=AUGMENTED, seed=0,
           refill=True):
    st_ = ODSState.create(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    for j in range(jobs):
        st_.register_job(j)
    cached = rng.choice(n, int(n * cached_frac), replace=False)
    st_.mark_cached(cached, form)
    samplers = {j: EpochSampler(n, batch, seed + 7 * j) for j in range(jobs)}
    seen = {j: set() for j in range(jobs)}
    for _ in range(steps):
        for j in range(jobs):
            b, ev = st_.sample_batch(j, samplers[j].next_request())
            yield j, b, ev, st_, seen
            if refill and len(ev):
                pool = np.flatnonzero(st_.status == IN_STORAGE)
                st_.mark_cached(rng.permutation(pool)[:len(ev)], form)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(60, 400), batch=st.integers(4, 30),
       jobs=st.integers(1, 3), frac=st.floats(0.0, 0.9))
def test_no_duplicates_within_epoch(n, batch, jobs, frac):
    """Property 1: a job never sees a sample twice within an epoch."""
    epoch_len = (n // batch) * batch
    for j, b, ev, st_, seen in _drive(n, batch, jobs, frac,
                                      steps=3 * n // batch):
        assert len(set(b.tolist())) == len(b)
        dup = seen[j] & set(b.tolist())
        assert not dup, f"job {j} resaw {sorted(dup)[:3]}"
        seen[j] |= set(b.tolist())
        if len(seen[j]) >= epoch_len:
            seen[j] = set()


def test_full_epoch_coverage_when_divisible():
    """Property 1b: with B | N every sample is served exactly once/epoch."""
    n, batch = 300, 30
    served = set()
    for j, b, ev, st_, seen in _drive(n, batch, 1, 0.5,
                                      steps=n // batch):
        served |= set(b.tolist())
    assert served == set(range(n))


def test_augmented_never_reused_across_epochs():
    """Property 2: refcount threshold (=n_jobs) evicts augmented samples
    after every job consumed them once."""
    n, batch, jobs = 200, 20, 2
    use_count = {}
    for j, b, ev, st_, seen in _drive(n, batch, jobs, 0.4,
                                      steps=4 * n // batch, refill=False):
        for sid in b[st_.status[b] == AUGMENTED]:
            use_count[sid] = use_count.get(sid, 0) + 1
    assert use_count, "no augmented hits happened"
    assert max(use_count.values()) <= jobs


def test_substitution_prefers_cached():
    st_ = ODSState.create(100, seed=0)
    st_.register_job(0)
    st_.mark_cached(np.arange(50), ENCODED)
    req = np.arange(50, 80)                    # all misses
    batch, _ = st_.sample_batch(0, req)
    assert np.all(st_.status[batch] == ENCODED), \
        "all misses should be substituted by cached unseen samples"


def test_ods_randomness_across_seeds():
    """Property 3: the delivered order depends on the PRNG seed."""
    outs = []
    for seed in (0, 1):
        st_ = ODSState.create(100, seed=seed)
        st_.register_job(0)
        st_.mark_cached(np.arange(0, 100, 2), ENCODED)
        batch, _ = st_.sample_batch(0, np.arange(1, 100, 2)[:20])
        outs.append(tuple(batch.tolist()))
    assert outs[0] != outs[1]


def test_metadata_footprint_matches_paper():
    """§5.2: 8 jobs x 1.3M samples ~ 2.6MB of ODS metadata."""
    st_ = ODSState.create(1_300_000)
    for j in range(8):
        st_.register_job(j)
    mb = st_.metadata_bytes() / 1e6
    assert 2.0 <= mb <= 3.5, mb


def test_hit_rate_exceeds_cache_fraction_with_churn():
    """Fig. 13 mechanism: with eviction+refill, ODS hit rate beats the
    static cached fraction."""
    last = None
    for j, b, ev, st_, seen in _drive(1000, 50, 2, 0.3,
                                      steps=4 * 1000 // 50):
        last = st_
    assert last.hit_rate() > 0.4, last.hit_rate()
