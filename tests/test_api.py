"""repro.api facade: sessions, pluggable policies, backend parity.

The parity tests pin the documented invariant-level equivalence between
the NumPy ODS and the jittable JAX twin behind the same session API:
both prefer cached-unseen samples over storage fetches, both serve every
sample exactly once per job per epoch, and both evict augmented entries
at refcount == n_jobs — they do NOT agree on which random cached sample
fills a given slot (different PRNG mechanics, see ods_jax's module doc).
"""
import numpy as np
import pytest

from repro.api import (AZURE_NC96, DatasetProfile, SenecaConfig,
                       SenecaServer, SessionClosed, policy_names,
                       resolve_policy)

BACKENDS = ("numpy", "jax")


def _server(n=200, cache_bytes=None, split=(0.0, 0.0, 1.0), seed=3,
            **kw) -> SenecaServer:
    profile = DatasetProfile("synth", n, 1000, decoded_bytes=1000,
                             augmented_bytes=1000)
    return SenecaServer(SenecaConfig(
        cache_bytes=cache_bytes if cache_bytes is not None else 1000 * n,
        hardware=AZURE_NC96, dataset=profile, split=split, seed=seed, **kw))


# ----------------------------------------------------------------------
# session lifecycle
def test_open_session_hides_job_plumbing():
    server = _server()
    with server.open_session(batch_size=10) as sess:
        ids, forms = sess.next_batch_ids()
        assert ids.shape == (10,) and forms.shape == (10,)
        assert server.n_sessions == 1
        st = sess.stats()
        assert st["session"]["batch_size"] == 10
    assert server.n_sessions == 0


def test_closed_session_raises_clear_error():
    server = _server()
    sess = server.open_session(batch_size=8)
    sess.next_batch_ids()
    sess.close()
    with pytest.raises(SessionClosed, match="closed.*open_session"):
        sess.next_batch_ids()
    sess.close()                                   # idempotent
    # racing admissions from pipeline workers are dropped, not an error
    assert sess.admit(0, "augmented", b"v", 1000) is False


def test_server_close_closes_all_sessions():
    server = _server()
    sessions = [server.open_session(batch_size=4) for _ in range(3)]
    assert server.service.backend.n_jobs == 3
    server.close()
    assert server.n_sessions == 0
    for s in sessions:
        with pytest.raises(SessionClosed):
            s.next_batch_ids()


@pytest.mark.parametrize("backend", BACKENDS)
def test_session_churn_keeps_ods_metadata_consistent(backend):
    """Opening/closing sessions mid-run tracks the n_jobs refcount
    threshold and the per-job metadata footprint."""
    server = _server(backend=backend)
    eng = server.service
    s1 = server.open_session(batch_size=10)
    assert eng.backend.n_jobs == 1
    base_meta = eng.backend.metadata_bytes()

    # with one job, an augmented entry dies after a single serve — and as
    # the only cached entry it is guaranteed to be substituted into the
    # very first batch
    assert s1.admit(5, "augmented", b"v", 1000)
    ids, _ = s1.next_batch_ids()
    assert 5 in ids.tolist()
    assert eng.backend.status_of(np.array([5]))[0] == 0, \
        "threshold 1: first serve must refcount-evict"

    # second session raises the threshold to 2 mid-run; admit an entry no
    # job has seen yet so its refcount starts at 0
    s2 = server.open_session(batch_size=10)
    assert eng.backend.n_jobs == 2
    assert eng.backend.metadata_bytes() > base_meta
    fresh = next(i for i in range(200)
                 if i not in set(ids.tolist()) and i != 5)
    assert s2.admit(fresh, "augmented", b"v", 1000)
    for _ in range(200 // 10 - 1):           # s1 finishes its epoch alone
        s1.next_batch_ids()
    assert eng.backend.status_of(np.array([fresh]))[0] == 3, \
        "threshold 2: one job's serve must NOT evict"
    for _ in range(200 // 10):               # s2's epoch is the second use
        s2.next_batch_ids()
    assert eng.backend.status_of(np.array([fresh]))[0] == 0, \
        "threshold 2: the second job's serve completes the refcount"

    # closing s2 drops the threshold back; metadata shrinks
    s2.close()
    assert eng.backend.n_jobs == 1
    assert eng.backend.metadata_bytes() == base_meta
    s1.close()


# ----------------------------------------------------------------------
# policies
def test_policy_registry_names_and_errors():
    assert "ods" in policy_names("sampler")
    assert "naive" in policy_names("sampler")
    assert "unseen-only" in policy_names("admission")
    assert "capacity" in policy_names("admission")
    assert "refcount" in policy_names("eviction")
    assert "lru" in policy_names("eviction")
    with pytest.raises(ValueError, match="unknown sampler policy"):
        resolve_policy("sampler", "nope")
    with pytest.raises(ValueError, match="unknown policy kind"):
        from repro.api import register_policy
        register_policy("frobnicator", "x", object)


def test_naive_sampler_serves_exactly_requested():
    server = _server(use_ods=False)
    stats = server.stats()
    assert stats["policies"]["sampler"] == "naive"
    assert stats["policies"]["admission"] == "capacity"
    with server.open_session(batch_size=10) as sess:
        seen = []
        for _ in range(200 // 10):
            ids, _ = sess.next_batch_ids()
            seen.extend(ids.tolist())
        assert sorted(seen) == list(range(200))
    stats = server.stats()
    assert stats["substitutions"] == 0
    assert stats["hits"] + stats["misses"] == 200


def test_lru_eviction_baseline_churns_instead_of_rejecting():
    server = _server(cache_bytes=3 * 1000, eviction="lru",
                     sampler="naive", admission="capacity")
    eng = server.service
    assert eng.cache.parts["augmented"].policy == "lru"
    with server.open_session(batch_size=4):
        for sid in range(5):                     # capacity: 3 entries
            assert eng.admit(sid, "augmented", b"v", 1000)
        resident = eng.cache.parts["augmented"].keys()
        assert len(resident) == 3
        assert 0 not in resident and 4 in resident   # oldest evicted


def test_unseen_only_admission_rejects_all_seen_augmented():
    server = _server()
    eng = server.service
    with server.open_session(batch_size=10) as sess:
        ids, _ = sess.next_batch_ids()           # all misses -> all seen
        sid = int(ids[0])
        assert not eng.admit(sid, "augmented", b"v", 1000), \
            "augmented admission nobody can consume must be rejected"
        assert eng.admit(sid, "encoded", b"v", 1000) or \
            eng.tier_capacity("encoded") == 0    # other forms unaffected


# ----------------------------------------------------------------------
# backend parity (acceptance: same request stream, same invariants)
def _drive_epoch(server, n, B, n_cached):
    """Open two sessions, admit n_cached augmented entries, run exactly one
    epoch for each job, returning (per-job id lists, first batches)."""
    s1 = server.open_session(batch_size=B)
    s2 = server.open_session(batch_size=B)
    for sid in range(n_cached):
        assert s1.admit(sid, "augmented", b"v", 1000)
    first = {}
    seen = {0: [], 1: []}
    for step in range(n // B):
        for jid, sess in ((0, s1), (1, s2)):
            ids, forms = sess.next_batch_ids()
            if step == 0:
                first[jid] = forms
            seen[jid].extend(ids.tolist())
    s1.close()
    s2.close()
    return seen, first


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_invariants_per_backend(backend):
    n, B, n_cached = 96, 8, 48
    server = _server(n=n, backend=backend)
    seen, first = _drive_epoch(server, n, B, n_cached)

    # invariant 1: every sample exactly once per job per epoch
    for jid in (0, 1):
        assert sorted(seen[jid]) == list(range(n)), backend

    # invariant 2: cached-unseen preferred — with half the dataset cached
    # and batch << cached count, job 0's whole first batch is served from
    # cache in both backends (misses are substituted).  Job 1's first
    # batch can contain entries its own serve just refcount-evicted, so
    # only the first-served session gives a clean read.
    assert np.all(first[0] != 0), (backend, first[0])

    # invariant 3: refcount eviction at n_jobs — after one full epoch for
    # both jobs every admitted augmented entry has been consumed by both
    # and must be back to storage-resident
    status = server.service.backend.status_of(np.arange(n))
    assert int((status == 3).sum()) == 0, backend

    stats = server.stats()
    assert stats["hits"] > 0 and stats["substitutions"] > 0


def test_parity_numpy_vs_jax_same_stream_same_aggregates():
    """Same config, same seeds, same request stream: the two backends must
    agree on the invariant-level aggregates (coverage and full eviction),
    and their hit counts must land in the same regime."""
    n, B, n_cached = 96, 8, 48
    out = {}
    for backend in BACKENDS:
        server = _server(n=n, backend=backend)
        seen, _ = _drive_epoch(server, n, B, n_cached)
        st = server.stats()
        out[backend] = {
            "coverage": {j: sorted(seen[j]) for j in seen},
            "aug_left": int((server.service.backend.status_of(
                np.arange(n)) == 3).sum()),
            "hits": st["hits"], "total": st["hits"] + st["misses"],
        }
    a, b = out["numpy"], out["jax"]
    assert a["coverage"] == b["coverage"] == \
        {0: list(range(n)), 1: list(range(n))}
    assert a["aug_left"] == b["aug_left"] == 0
    assert a["total"] == b["total"] == 2 * n
    # every cached entry is served to each job exactly once before dying,
    # so both backends must count exactly n_cached hits per job
    assert a["hits"] == b["hits"] == 2 * n_cached


# ----------------------------------------------------------------------
# batch-granular admission (the stage-parallel executor's path): same
# policy decisions as N per-sample admits, one lock acquisition per batch
def test_admit_batch_matches_per_sample_admission():
    """Same entries, same policies, same capacity: admit_batch and a loop
    of admit() must leave identical residency, stats and ODS status."""
    entries = [(sid, b"v", 1000) for sid in range(5)]
    per, batch = _server(cache_bytes=3 * 1000), _server(cache_bytes=3 * 1000)
    s1 = per.open_session(batch_size=4)
    s2 = batch.open_session(batch_size=4)
    loop_ok = [s1.admit(sid, "augmented", v, nb) for sid, v, nb in entries]
    batch_ok = s2.admit_batch("augmented", entries)
    assert loop_ok == batch_ok.tolist() == [True] * 3 + [False] * 2
    assert per.service.cache.parts["augmented"].keys() == \
        batch.service.cache.parts["augmented"].keys()
    ids = np.arange(5)
    assert np.array_equal(per.service.backend.status_of(ids),
                          batch.service.backend.status_of(ids))
    s1.close()
    s2.close()


def test_admit_batch_unseen_only_rejects_all_seen():
    server = _server()
    with server.open_session(batch_size=10) as sess:
        ids, _ = sess.next_batch_ids()          # all misses -> all seen
        entries = [(int(s), b"v", 1000) for s in ids]
        assert not sess.admit_batch("augmented", entries).any(), \
            "augmented admissions nobody can consume must all be rejected"
        fresh = [(sid, b"v", 1000) for sid in range(200)
                 if sid not in set(ids.tolist())][:10]
        ok = sess.admit_batch("augmented", fresh)
        assert ok.all()
        marked = server.service.backend.status_of(
            np.asarray([sid for sid, _, _ in fresh]))
        assert (marked == 3).all(), "admitted batch must be ODS-marked"


def test_admit_batch_closed_session_drops_everything():
    server = _server()
    sess = server.open_session(batch_size=4)
    sess.close()
    ok = sess.admit_batch("augmented", [(0, b"v", 1000), (1, b"v", 1000)])
    assert ok.shape == (2,) and not ok.any()
    assert len(server.service.cache.parts["augmented"]) == 0


def test_admit_batch_zero_capacity_tier_fast_path():
    server = _server(split=(1.0, 0.0, 0.0))     # no augmented tier
    with server.open_session(batch_size=4) as sess:
        ok = sess.admit_batch("augmented", [(0, b"v", 1000)])
        assert not ok.any()
        assert sess.admit_batch("encoded", [(0, b"e", 1000)]).all()


# ----------------------------------------------------------------------
# legacy DSIPipeline shim (scheduled for removal, see repro.core.seneca):
# pin the positional-argument handling so dropping it in a later PR is a
# deliberate act, not a silent break
def test_legacy_dsipipeline_positional_batch_size():
    from repro.data.pipeline import DSIPipeline
    from repro.data.storage import RemoteStorage
    from repro.data.synthetic import tiny

    ds = tiny(n=64)
    server = _server(n=64, cache_bytes=64 * 4 * ds.augmented_bytes())
    storage = RemoteStorage(ds)
    # old positional form: DSIPipeline(job_id, service, storage, batch_size)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        pipe = DSIPipeline(7, server.service, storage, 8)
    assert pipe.session.job_id == 7 and pipe.bs == 8
    batch = pipe.next_batch()
    assert batch["images"].shape[0] == 8
    pipe.stop()
    # keyword batch_size on the legacy form also still works
    with pytest.warns(DeprecationWarning):
        pipe2 = DSIPipeline(8, server.service, storage, batch_size=4)
    assert pipe2.bs == 4
    pipe2.stop()
    server.close()


def test_legacy_dsipipeline_bad_args_raise():
    from repro.data.pipeline import DSIPipeline
    from repro.data.storage import RemoteStorage
    from repro.data.synthetic import tiny

    ds = tiny(n=32)
    server = _server(n=32)
    # session-style call with a non-storage second arg
    with pytest.raises(TypeError, match="RemoteStorage"):
        DSIPipeline(server.open_session(batch_size=4), object())
    # legacy call missing batch_size entirely
    with pytest.warns(DeprecationWarning), \
            pytest.raises(TypeError, match="legacy"):
        DSIPipeline(1, server.service, RemoteStorage(ds))
    server.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_selectable_from_server_kwarg(backend):
    profile = DatasetProfile("synth", 64, 1000, decoded_bytes=1000,
                             augmented_bytes=1000)
    cfg = SenecaConfig(cache_bytes=64000, hardware=AZURE_NC96,
                       dataset=profile, split=(0.0, 0.0, 1.0))
    server = SenecaServer(cfg, backend=backend)
    assert server.stats()["backend"] == backend
    with server.open_session(batch_size=8) as sess:
        ids, _ = sess.next_batch_ids()
        assert len(ids) == 8
