"""Roofline machinery: loop-aware collective parsing + term derivation."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import TRAIN_4K, PREFILL_32K, DECODE_32K
from repro.roofline import analysis, hlo_collectives

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_loop_aware_collective_bytes_exact():
    """Ground truth: a 5-layer scan whose grad triggers one ring all-reduce
    per layer of a known size — the parser must multiply by the trip count
    and apply the ring factor exactly."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed.compat import set_mesh
        from repro.roofline import hlo_collectives
        mesh = Mesh(np.asarray(jax.devices()[:4]), ('d',))
        def f(x, w):
            def body(h, wi):
                y = jax.lax.with_sharding_constraint(h @ wi, P('d', None))
                return y, None
            out, _ = jax.lax.scan(body, x, w)
            return out.sum()
        x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
        with set_mesh(mesh):
            c = jax.jit(jax.grad(f, argnums=1),
                        in_shardings=(NamedSharding(mesh, P('d', None)),
                                      NamedSharding(mesh, P())),
                        out_shardings=NamedSharding(mesh, P())
                        ).lower(x, w).compile()
        st = hlo_collectives.analyze(c.as_text())
        # 5 iterations x (64*64*4 B) x ring factor 2*(4-1)/4
        assert st.per_kind_count['all-reduce'] == 5, st.per_kind_count
        assert abs(st.total_wire_bytes - 5 * 16384 * 1.5) < 1, \\
            st.total_wire_bytes
        print('OK')
    """)], capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_ring_factors():
    line_ar = ("%x = f32[100]{0} all-reduce(%y), "
               "replica_groups=[1,4]<=[4]")
    line_ag = ("%x = f32[400]{0} all-gather(%y), "
               "replica_groups=[1,4]<=[4]")
    st = hlo_collectives.analyze(line_ar + "\n" + line_ag)
    # all-reduce: 400B * 2 * 3/4; all-gather: 1600B * 3/4
    assert abs(st.per_kind_bytes["all-reduce"] - 600) < 1
    assert abs(st.per_kind_bytes["all-gather"] - 1200) < 1


def test_model_flops_scaling():
    cfg = registry.get("deepseek-7b")
    train = analysis.model_flops(cfg, TRAIN_4K)
    prefill = analysis.model_flops(cfg, PREFILL_32K)
    decode = analysis.model_flops(cfg, DECODE_32K)
    # train ~ 6ND on 1M tokens; prefill fwd-only on the same token count
    assert train > prefill > decode
    n_tok_train = TRAIN_4K.global_batch * TRAIN_4K.seq_len
    assert train > 6 * cfg.n_params() * n_tok_train * 0.9


def test_moe_uses_active_params():
    dense = registry.get("deepseek-7b")
    moe = registry.get("deepseek-moe-16b")
    f = analysis.model_flops(moe, TRAIN_4K)
    # 16.9B total but 2.8B active: flops must track active, not total
    assert f < 6 * moe.n_params() * TRAIN_4K.global_batch * \
        TRAIN_4K.seq_len * 0.5


def test_record_bottleneck_and_fraction():
    cfg = registry.get("deepseek-7b")
    rec = analysis.build_record(
        arch="deepseek-7b", shape=TRAIN_4K, cfg=cfg, mesh_name="16x16",
        chips=256, cost={"flops": 1e15, "bytes accessed": 1e12},
        wire_bytes=1e11, collectives={"all-reduce": 1e11})
    assert rec.bottleneck in ("compute", "memory", "collective")
    assert 0 < rec.roofline_fraction <= 1.0
    terms = {"compute": rec.t_compute, "memory": rec.t_memory,
             "collective": rec.t_collective}
    assert rec.bottleneck == max(terms, key=terms.get)


def test_memory_ledger_kimi_needs_scale_out():
    from repro.roofline.memory_ledger import build_ledger
    cfg = registry.get("kimi-k2-1t-a32b")
    par = registry.default_parallelism(cfg, TRAIN_4K)
    led = build_ledger(cfg, TRAIN_4K, par)
    # 1T params + int8 moments over 256 chips: states alone ~16 GB/chip
    assert led.params > 7e9
    assert not led.fits()
    assert led.pods_needed() >= 1


def test_memory_ledger_small_arch_fits():
    from repro.roofline.memory_ledger import build_ledger
    cfg = registry.get("internvl2-2b")
    par = registry.default_parallelism(cfg, DECODE_32K)
    led = build_ledger(cfg, DECODE_32K, par)
    assert led.fits(), led.as_dict()
