"""Recompute-cost-aware ("cost", GDSF) eviction.

Tier-level: the DRAM tier under ``policy="cost"`` scores entries by
``inflation + recompute_cost / nbytes``, evicts the minimum, and ages
the pool by raising the inflation floor to each victim's priority.
Policy-level: ``resolve_policy("eviction", "cost")`` wires every
partition to the cost engine and ``refresh`` converts telemetry stage
latencies into per-form recompute costs (fetch / fetch+decode /
fetch+decode+augment chains).
"""
import numpy as np
import pytest

from repro.api import SenecaServer, resolve_policy
from repro.api.policies import CostAwareEviction
from repro.api.telemetry import TelemetryAggregator
from repro.cache.store import TieredCache
from repro.cache.tiers import DramTier
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny


# ----------------------------------------------------------------------
# DramTier "cost" mechanics
def test_cost_tier_evicts_cheapest_per_byte_first():
    tier = DramTier(100, "cost")
    tier.put(1, b"a", 50)        # priority = cost/50 (small = expensive/B)
    tier.put(2, b"b", 25)        # priority = cost/25
    tier.put(3, b"c", 25)
    # making room must pick the *largest* entry (lowest
    # recompute-cost-per-byte), not the oldest
    evicted = tier.put(4, b"d", 40)
    assert [k for k, _v, _n in evicted] == [1]
    assert 2 in tier and 3 in tier and 4 in tier


def test_cost_tier_respects_recompute_cost():
    tier = DramTier(100, "cost")
    tier.put(1, b"cheap", 10)
    tier.set_cost(100.0)          # later entries are pricey to rebuild
    tier.put(2, b"dear", 10)
    evicted = tier.set_capacity(15)
    # same size, but entry 1 scored with cost 1.0 and entry 2 with 100.0
    assert [k for k, _v, _n in evicted] == [1]
    assert 2 in tier


def test_cost_tier_touch_rescues_hot_entries():
    tier = DramTier(30, "cost")
    tier.put(1, b"a", 10)
    tier.put(2, b"b", 10)
    tier.put(3, b"c", 10)
    evicted = tier.put(4, b"d", 10)   # evicts 1, raises inflation
    assert [k for k, _v, _n in evicted] == [1]
    # a touched survivor re-scores at the inflated floor, so the next
    # victim is the untouched old entry, not the hot one
    assert tier.get(2) == b"b"
    evicted = tier.put(5, b"e", 10)
    assert [k for k, _v, _n in evicted] == [3]
    assert 2 in tier


def test_cost_tier_inflation_ages_old_entries():
    tier = DramTier(20, "cost")
    tier.put(1, b"a", 10)
    tier.put(2, b"b", 10)
    tier.put(3, b"c", 10)         # evicts 1, inflation rises to 1's pri
    assert 1 not in tier
    # a fresh entry now scores above the pre-inflation survivors, so the
    # next victim is the remaining old entry, not the newcomer
    evicted = tier.put(4, b"d", 10)
    assert [k for k, _v, _n in evicted] == [2]
    assert 3 in tier and 4 in tier
    assert tier._inflation > 0.0


def test_cost_tier_accounting_stays_consistent():
    tier = DramTier(64, "cost")
    rng = np.random.default_rng(0)
    for i in range(200):
        k = int(rng.integers(0, 20))
        op = int(rng.integers(0, 3))
        if op == 0:
            tier.put(k, bytes(2), int(rng.integers(1, 32)))
        elif op == 1:
            tier.get(k)
        else:
            tier.remove(k)
    assert tier.stats.bytes_used == sum(tier._sizes.values())
    assert tier.stats.bytes_used <= tier.capacity
    assert set(tier._pri) == set(tier._data)


# ----------------------------------------------------------------------
# policy registration + telemetry-fed refresh
def test_cost_policy_resolves_and_partitions():
    pol = resolve_policy("eviction", "cost")
    assert isinstance(pol, CostAwareEviction) and pol.name == "cost"
    assert set(pol.partition_policies().values()) == {"cost"}
    assert pol.threshold(None) is None


def test_cost_refresh_builds_stage_chains():
    cache = TieredCache(3_000, (0.4, 0.3, 0.3),
                        evict_policies={"encoded": "cost",
                                        "decoded": "cost",
                                        "augmented": "cost"})
    tele = TelemetryAggregator()
    pol = CostAwareEviction()
    # cold telemetry (all-None latencies): defaults survive, no crash
    costs = pol.refresh(cache, tele.snapshot())
    assert costs == CostAwareEviction.DEFAULT_COSTS
    for _ in range(4):
        tele.record_stage("fetch_storage", 0.010)
        tele.record_stage("decode", 0.004)
        tele.record_stage("augment", 0.002)
    costs = pol.refresh(cache, tele.snapshot())
    assert costs["encoded"] == pytest.approx(0.010)
    assert costs["decoded"] == pytest.approx(0.014)
    assert costs["augmented"] == pytest.approx(0.016)
    for form, cost in costs.items():
        assert cache.parts[form].dram.recompute_cost == \
            pytest.approx(cost), form
    cache.close()


def test_cost_refresh_partial_telemetry_keeps_defaults():
    cache = TieredCache(3_000, (0.4, 0.3, 0.3),
                        evict_policies={"encoded": "cost",
                                        "decoded": "cost",
                                        "augmented": "cost"})
    tele = TelemetryAggregator()
    tele.record_stage("fetch_storage", 0.010)
    costs = CostAwareEviction().refresh(cache, tele.snapshot())
    assert costs["encoded"] == pytest.approx(0.010)
    # decode/augment unseen: their chain keeps the default weights
    assert costs["decoded"] == CostAwareEviction.DEFAULT_COSTS["decoded"]
    assert costs["augmented"] == \
        CostAwareEviction.DEFAULT_COSTS["augmented"]
    cache.close()


def test_server_runs_with_cost_eviction():
    ds = tiny(n=64)
    server = SenecaServer.for_dataset(ds, cache_frac=0.25, seed=0,
                                      eviction="cost")
    with server.open_session(batch_size=16) as sess:
        pipe = DSIPipeline(sess, RemoteStorage(ds), n_workers=2)
        for _ in range(8):         # > 1 epoch: evictions + refresh tick
            batch = pipe.next_batch()
            assert batch["images"].shape[0] == 16
        stats = sess.stats()
        pipe.stop()
    assert stats["hits"] + stats["misses"] > 0
    assert server.stats()["policies"]["eviction"] == "cost"
    server.close()
