"""DSI performance model (Eqs. 1-9) + MDP properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mdp
from repro.core.perf_model import (AZURE_NC96, DATASETS, EVAL_PROFILES,
                                   IMAGENET_1K, IMAGENET_22K, IN_HOUSE,
                                   OPENIMAGES, DatasetProfile,
                                   HardwareProfile, JobProfile,
                                   dsi_throughput, GB, Gbit, MB, KB)
from dataclasses import replace


def test_min_form_bounds():
    """No DSI path can exceed GPU ingestion or the pipeline min."""
    out = dsi_throughput(IN_HOUSE, IMAGENET_1K, JobProfile(), 0.4, 0.3, 0.3)
    n = IN_HOUSE.n_nodes
    for v in (out.dsi_a, out.dsi_d, out.dsi_e, out.dsi_s):
        assert v <= n * IN_HOUSE.t_gpu + 1e-9
    assert out.dsi_e <= n * IN_HOUSE.t_da + 1e-9
    assert out.dsi_d <= n * IN_HOUSE.t_a + 1e-9
    assert out.dsi_s <= out.dsi_e + 1e-9                      # Eq. 7


def test_population_conservation():
    out = dsi_throughput(AZURE_NC96, OPENIMAGES, JobProfile(), 0.2, 0.5, 0.3)
    total = out.n_a + out.n_d + out.n_e + out.n_storage
    assert abs(total - OPENIMAGES.n_total) < 1.0              # Eq. 8


def test_overall_is_weighted_mean():
    out = dsi_throughput(IN_HOUSE, IMAGENET_1K, JobProfile(), 1.0, 0.0, 0.0)
    lo = min(out.dsi_e, out.dsi_s)
    hi = max(out.dsi_e, out.dsi_s)
    assert lo - 1e-9 <= out.overall <= hi + 1e-9


@settings(max_examples=30, deadline=None)
@given(scale=st.floats(1.1, 8.0))
def test_monotonic_in_bandwidth(scale):
    """More of any bandwidth never reduces predicted throughput."""
    base = dsi_throughput(IN_HOUSE, OPENIMAGES, JobProfile(),
                          0.4, 0.3, 0.3).overall
    for field in ("b_cache", "b_storage", "b_nic", "b_pcie"):
        hw = replace(IN_HOUSE, **{field: getattr(IN_HOUSE, field) * scale})
        up = dsi_throughput(hw, OPENIMAGES, JobProfile(),
                            0.4, 0.3, 0.3).overall
        assert up >= base - 1e-9, field


@settings(max_examples=25, deadline=None)
@given(xe=st.floats(0, 1), xd=st.floats(0, 1))
def test_vectorized_matches_scalar(xe, xd):
    if xe + xd > 1:
        xe, xd = xe / 2, xd / 2
    xa = 1 - xe - xd
    s = dsi_throughput(AZURE_NC96, IMAGENET_1K, JobProfile(), xe, xd, xa)
    v = dsi_throughput(AZURE_NC96, IMAGENET_1K, JobProfile(),
                       np.array([xe, 0.1]), np.array([xd, 0.2]),
                       np.array([xa, 0.7]))
    assert np.isclose(float(v.overall[0]), float(s.overall))


def test_simplex_grid_complete():
    xe, xd, xa = mdp.simplex_grid(0.01)
    assert len(xe) == 5151                    # C(102,2)
    assert np.allclose(xe + xd + xa, 1.0)


def test_mdp_beats_or_ties_paper_splits():
    """Our brute-force optimum >= the paper's Table 6 split throughput
    under the same equations (core soundness of MDP)."""
    paper = {
        ("imagenet-1k", "in-house"): (0.58, 0.42, 0.0),
        ("imagenet-1k", "azure-nc96ads"): (0.0, 0.48, 0.52),
        ("openimages-v7", "azure-nc96ads"): (0.05, 0.95, 0.0),
        ("imagenet-22k", "azure-nc96ads"): (1.0, 0.0, 0.0),
    }
    for (ds_name, hw_name), split in paper.items():
        ds = next(d for d in DATASETS if d.name == ds_name)
        hw = next(h for h in EVAL_PROFILES if h.name == hw_name)
        ours = mdp.optimize(hw, ds)
        theirs = float(dsi_throughput(hw, ds, JobProfile(), *split).overall)
        assert ours.throughput >= theirs - 1e-6, (ds_name, hw_name)


def test_mdp_imagenet22k_all_encoded():
    """Table 6: the 1.4TB dataset forces a pure encoded cache on Azure."""
    p = mdp.optimize(next(h for h in EVAL_PROFILES
                          if h.name == "azure-nc96ads"), IMAGENET_22K)
    assert p.x_e >= 0.9


def test_mdp_openimages_azure_decoded():
    """Table 6 marquee cell: OpenImages/Azure is decoded-dominated
    (paper: 5-95-0)."""
    p = mdp.optimize(next(h for h in EVAL_PROFILES
                          if h.name == "azure-nc96ads"), OPENIMAGES)
    assert p.x_d >= 0.5


def test_mdp_fast_enough():
    import time
    t0 = time.monotonic()
    mdp.optimize(AZURE_NC96, IMAGENET_1K)
    assert time.monotonic() - t0 < 1.0        # paper: "<1s"


def test_nvlink_zeroes_pcie_overhead():
    hw = replace(IN_HOUSE, nvlink_intra=True, gpus_per_node=8)
    job = JobProfile(model_bytes=2_000 * MB, batch_size=32)
    base = dsi_throughput(IN_HOUSE, IMAGENET_1K, job, 0, 0, 1).overall
    nv = dsi_throughput(hw, IMAGENET_1K, job, 0, 0, 1).overall
    assert nv >= base - 1e-9
