"""Property tests for TieredCache invariants (ISSUE-4 satellite).

Random interleavings of ``insert_batch_gated`` / ``resize`` / ``lookup``
/ eviction driven through the :class:`SenecaService` admission +
demotion paths must never:

* exceed any partition's byte capacity;
* desynchronize a partition's byte accounting from its entry sizes;
* leave ODS metadata claiming a form the cache does not hold — the
  one-directional consistency contract: ``status[k] == f > 0`` implies
  the cache is resident at form ``f`` for ``k`` (understating — status 0
  while a copy is still resident — is allowed: it only costs a refetch,
  never serves wrong data).

Strategies stick to the subset the conftest hypothesis fallback shim
implements (integers/floats/lists/tuples/sampled_from), so the
properties run with seeded examples even when the real library is
absent.
"""
import os
import shutil
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import AZURE_NC96, DatasetProfile, SenecaConfig, SenecaService
from repro.api.backends import resolve_backend
from repro.api.server import CODE_FORM, FORM_CODE
from repro.cache.store import FORMS, TieredCache

# property sweeps are the "tier-1 stays fast" satellite's slow half:
# deselected from tier-1 by pytest.ini, run by the CI stress job
pytestmark = pytest.mark.slow

N_KEYS = 64
CACHE_BYTES = 8_192

OPS = ("admit_encoded", "admit_decoded", "admit_augmented",
       "admit_many", "lookup", "evict_augmented", "resize")

# one op: (kind, key, nbytes, f_enc, f_rest) — the two floats become a
# resize split; admits ignore them, resizes ignore key/nbytes
op_strategy = st.lists(
    st.tuples(st.sampled_from(OPS),
              st.integers(0, N_KEYS - 1),
              st.integers(1, 2_000),
              st.floats(0.0, 1.0),
              st.floats(0.0, 1.0)),
    min_size=1, max_size=60)


def _service(spill_dir=None, eviction=None) -> SenecaService:
    profile = DatasetProfile("prop", N_KEYS, 1_000, decoded_bytes=1_500,
                             augmented_bytes=2_000)
    return SenecaService(SenecaConfig(
        cache_bytes=CACHE_BYTES, hardware=AZURE_NC96, dataset=profile,
        split=(0.4, 0.3, 0.3), seed=3,
        spill_dir=spill_dir, spill_bytes=CACHE_BYTES if spill_dir else 0,
        spill_split=(0.4, 0.3, 0.3) if spill_dir else None,
        eviction=eviction))


def _split_from(f_enc: float, f_rest: float):
    """Map two unit floats to a valid (x_e, x_d, x_a) simplex point."""
    x_e = round(f_enc, 3)
    x_d = round((1.0 - x_e) * f_rest, 3)
    x_a = 1.0 - x_e - x_d
    return (x_e, x_d, x_a)


def _check_invariants(svc: SenecaService) -> None:
    # chains shed keys as a serving side effect (spill overflow,
    # promotion backfill); the service patches metadata at its regular
    # reconcile points — flush them before asserting consistency
    svc.reconcile_evictions()
    cache = svc.cache
    with cache.lock:
        total_cap = 0
        for form in FORMS:
            part = cache.parts[form]
            assert part.stats.bytes_used <= part.capacity, \
                f"{form}: {part.stats.bytes_used} > cap {part.capacity}"
            assert part.stats.bytes_used >= 0
            assert part.stats.bytes_used == sum(part._sizes.values()), \
                f"{form}: byte ledger out of sync with entry sizes"
            assert set(part._data) == set(part._sizes), \
                f"{form}: data/size key sets diverged"
            total_cap += part.capacity
            if part.spill is not None:
                spill = part.spill
                assert spill.stats.bytes_used <= spill.capacity, \
                    f"{form}: disk {spill.stats.bytes_used} > cap"
                assert spill.stats.bytes_used == sum(
                    spill.size_of(k) for k in spill.keys()), \
                    f"{form}: disk byte ledger out of sync"
                on_disk = set(os.listdir(spill.dir)) \
                    if os.path.isdir(spill.dir) else set()
                assert {f"{k}.bin" for k in spill.keys()} == on_disk, \
                    f"{form}: disk index diverged from files"
                assert not (set(part._data) & set(spill.keys())), \
                    f"{form}: key resident in both tiers"
        assert total_cap <= cache.capacity, \
            "partition capacities exceed the cache total"
        # ODS consistency: a nonzero status must name a resident form
        status = svc.backend.status_of(np.arange(N_KEYS))
        for key in np.flatnonzero(status):
            form = CODE_FORM[int(status[key])]
            assert int(key) in cache.parts[form], \
                f"status says {form} for key {key} but cache lost it"


@settings(max_examples=40)
@given(ops=op_strategy)
def test_tiered_cache_invariants_under_random_interleavings(ops):
    svc = _service()
    for kind, key, nbytes, f_enc, f_rest in ops:
        if kind.startswith("admit_") and kind != "admit_many":
            form = kind[len("admit_"):]
            svc.admit(key, form, b"x" * nbytes, nbytes)
        elif kind == "admit_many":
            # batch-granular admission across consecutive keys
            entries = [((key + i) % N_KEYS, b"y" * nbytes, nbytes)
                       for i in range(3)]
            svc.admit_batch("augmented" if f_rest >= 0.5 else "decoded",
                            entries)
        elif kind == "lookup":
            svc.lookup(key)
        elif kind == "evict_augmented":
            # the sampler's step-5 path: only keys the metadata sees as
            # augmented get evicted, and the status is patched with them
            if int(svc.backend.status_of(np.asarray([key]))[0]) \
                    == FORM_CODE["augmented"]:
                svc.cache.evict(key, "augmented")
                svc.backend.mark_evicted(np.asarray([key]))
        elif kind == "resize":
            from repro.core import mdp
            x_e, x_d, x_a = _split_from(f_enc, f_rest)
            svc.apply_partition(mdp.Partition(
                x_e, x_d, x_a, throughput=float("nan")))
        _check_invariants(svc)


@settings(max_examples=25)
@given(sizes=st.lists(st.tuples(st.integers(0, N_KEYS - 1),
                                st.integers(1, 3_000)),
                      min_size=1, max_size=40),
       f_enc=st.floats(0.0, 1.0), f_rest=st.floats(0.0, 1.0))
def test_insert_batch_gated_matches_looped_insert_gated(sizes, f_enc,
                                                        f_rest):
    """One insert_batch_gated call must leave the partition in exactly
    the state N looped insert_gated calls would (per-entry semantics),
    for any split geometry."""
    from repro.api.policies import resolve_policy
    split = _split_from(f_enc, f_rest)
    policy = resolve_policy("admission", "capacity")
    entries = [(k, b"z" * nb, nb) for k, nb in sizes]

    batch_cache = TieredCache(CACHE_BYTES, split)
    got = batch_cache.insert_batch_gated("decoded", entries, policy)

    loop_cache = TieredCache(CACHE_BYTES, split)
    want = [loop_cache.insert_gated(k, "decoded", v, nb, policy)
            for k, v, nb in entries]

    assert got == want
    bp, lp = batch_cache.parts["decoded"], loop_cache.parts["decoded"]
    assert bp.keys() == lp.keys()
    assert bp.stats.bytes_used == lp.stats.bytes_used <= bp.capacity


@settings(max_examples=25, deadline=None)
@given(ops=op_strategy)
def test_tier_chain_invariants_under_random_interleavings(ops):
    """The tentpole property: with a DRAM+disk chain under every
    partition, random admit/lookup(promote)/evict/resize(demote)
    interleavings keep the byte ledger exact across BOTH tiers, never
    leave a key in two tiers, never diverge the disk index from the
    files on disk, and keep ODS status one-directionally consistent
    with chain residency."""
    work = tempfile.mkdtemp(prefix="prop-spill-")
    try:
        svc = _service(spill_dir=work)
        for kind, key, nbytes, f_enc, f_rest in ops:
            if kind.startswith("admit_") and kind != "admit_many":
                form = kind[len("admit_"):]
                svc.admit(key, form, b"x" * nbytes, nbytes)
            elif kind == "admit_many":
                entries = [((key + i) % N_KEYS, b"y" * nbytes, nbytes)
                           for i in range(3)]
                svc.admit_batch("augmented" if f_rest >= 0.5
                                else "decoded", entries)
            elif kind == "lookup":
                svc.lookup(key)            # disk hits promote
            elif kind == "evict_augmented":
                if int(svc.backend.status_of(np.asarray([key]))[0]) \
                        == FORM_CODE["augmented"]:
                    svc.cache.evict(key, "augmented")
                    svc.backend.mark_evicted(np.asarray([key]))
            elif kind == "resize":
                from repro.core import mdp
                x_e, x_d, x_a = _split_from(f_enc, f_rest)
                y = _split_from(f_rest, f_enc)
                svc.apply_partition(
                    mdp.Partition(x_e, x_d, x_a, throughput=float("nan")),
                    mdp.Partition(*y, throughput=float("nan")))
            _check_invariants(svc)
        svc.close()
        leftovers = [f for _dp, _dn, fs in os.walk(work) for f in fs]
        assert not leftovers, f"close() leaked spill files: {leftovers}"
    finally:
        shutil.rmtree(work, ignore_errors=True)


@settings(max_examples=10, deadline=None)
@given(sizes=st.lists(st.integers(200, 1_200), min_size=2, max_size=10),
       backend_pick=st.floats(0.0, 1.0))
def test_demote_promote_round_trip_equality_all_forms(sizes, backend_pick):
    """Entries pushed down to disk and read back (promoted or not) are
    byte-identical for all three forms, on both ODS backends."""
    backend = "jax" if backend_pick >= 0.5 else "numpy"
    work = tempfile.mkdtemp(prefix="prop-rt-")
    try:
        svc = _service(spill_dir=work, eviction="lru")
        svc.backend = resolve_backend(backend, N_KEYS, seed=1)
        rng = np.random.default_rng(11)
        originals = {}
        for k, nb in enumerate(sizes):
            enc = bytes(rng.integers(0, 256, nb, dtype=np.uint8))
            dec = rng.integers(0, 256, (nb // 40 + 2, 5, 3)
                               ).astype(np.uint8)
            aug = rng.random((nb // 50 + 2, 4, 3)).astype(np.float32)
            originals[k] = (enc, dec, aug)
            svc.admit(k, "encoded", enc, len(enc))
            svc.admit(k, "decoded", dec, dec.nbytes)
            svc.admit(k, "augmented", aug, aug.nbytes)
        for k, (enc, dec, aug) in originals.items():
            with svc.cache.lock:
                got = {form: svc.cache.parts[form].peek(k)
                       for form in FORMS}
            for form, want in zip(FORMS, (enc, dec, aug)):
                if got[form] is None:
                    continue               # evicted out of the chain
                if form == "encoded":
                    assert bytes(got[form]) == want, (backend, form, k)
                else:
                    assert np.array_equal(np.asarray(got[form]), want), \
                        (backend, form, k)
            # promotion path serves the same content
            form, value = svc.cache.lookup(k)
            if form == "encoded":
                assert bytes(value) == originals[k][0]
        svc.close()
    finally:
        shutil.rmtree(work, ignore_errors=True)


@settings(max_examples=25)
@given(splits=st.lists(st.tuples(st.floats(0.0, 1.0),
                                 st.floats(0.0, 1.0)),
                       min_size=1, max_size=12),
       n_fill=st.integers(1, N_KEYS))
def test_resize_sequences_keep_exact_byte_accounting(splits, n_fill):
    """Any sequence of live resizes preserves per-partition capacity
    bounds and exact byte ledgers, with entries demoted in ODS metadata
    as partitions shrink."""
    from repro.core import mdp
    svc = _service()
    per = max(CACHE_BYTES // (2 * max(n_fill, 1)), 64)
    for key in range(n_fill):
        svc.admit(key, "augmented", b"a" * per, per)
        svc.admit(key, "encoded", b"e" * (per // 2), per // 2)
    _check_invariants(svc)
    for f_enc, f_rest in splits:
        x_e, x_d, x_a = _split_from(f_enc, f_rest)
        svc.apply_partition(mdp.Partition(x_e, x_d, x_a,
                                          throughput=float("nan")))
        _check_invariants(svc)
