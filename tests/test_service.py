"""Sharded data plane: router, shards, transports, server integration.

Tier-1 coverage for ``repro.service`` (the hypothesis sweeps live in
``test_service_properties.py``, CI stress job):

* consistent-hash router — scalar/vector agreement, grouping, balance;
* CacheShard protocol handling (errors stay Responses, never raises);
* ShardedCache over the sim transport — the full TieredCache surface,
  eviction piggybacking, residency merges, per-shard spill dirs;
* the determinism acceptance gate — one 2-job VirtualClock trace run on
  ``shards=1`` (classic engine) and ``shards=2`` (sim transport)
  produces identical per-job sample-id sequences, and two fresh
  ``shards=2`` runs are byte-identical;
* process transport — spawn handshake, zero-copy payload parity,
  shard-side produce parity, idempotent close, failed-start cleanup.
"""
import glob
import os
import tempfile

import numpy as np
import pytest

from repro.api import (JobSpec, SenecaServer, ShardedCache, ShardRouter,
                       VirtualClock, WorkloadRunner)
from repro.cache.store import FORMS, TieredCache
from repro.data.augment import augment_np
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.service import CacheShard, Request, Response, ShardConfig
from repro.service.router import _splitmix64_np, splitmix64
from repro.service.shard import produce_seed
from repro.workload.runner import deterministic_runner

SPLIT = (0.2, 0.4, 0.4)


# ----------------------------------------------------------------------
# router
def test_router_scalar_vector_agree():
    r = ShardRouter(5, vnodes=32, seed=3)
    keys = np.arange(512, dtype=np.int64)
    vec = r.shard_of_many(keys)
    assert [r.shard_of(int(k)) for k in keys] == list(vec)
    assert splitmix64(12345) == int(_splitmix64_np(
        np.asarray([12345], np.uint64))[0])


def test_router_group_partitions_exactly():
    r = ShardRouter(4, seed=1)
    keys = list(range(300))
    groups = r.group(keys)
    seen = sorted(int(keys[i]) for idx in groups.values() for i in idx)
    assert seen == keys
    for sid, idx in groups.items():
        assert all(r.shard_of(int(keys[int(i)])) == sid for i in idx)


def test_router_balance_and_range():
    r = ShardRouter(4, vnodes=64, seed=0)
    loads = r.load(np.arange(4000, dtype=np.int64))
    assert loads.sum() == 4000 and (loads > 0).all()
    assert loads.max() / loads.min() < 3.0


def test_router_single_shard_fast_path():
    r = ShardRouter(1, seed=9)
    assert r.shard_of(123) == 0
    assert (r.shard_of_many(np.arange(50)) == 0).all()


def test_router_grow_moves_keys_only_to_new_shard():
    keys = np.arange(3000, dtype=np.int64)
    before = ShardRouter(4, seed=7).shard_of_many(keys)
    after = ShardRouter(5, seed=7).shard_of_many(keys)
    moved = before != after
    assert 0 < moved.sum() < len(keys)
    assert (after[moved] == 4).all()


# ----------------------------------------------------------------------
# shard protocol
def _shard(**kw) -> CacheShard:
    cfg = ShardConfig(shard_id=0, n_shards=1, cache_bytes=200_000,
                      split=SPLIT, **kw)
    return CacheShard(cfg)


def test_shard_handles_unknown_op_and_bad_args():
    shard = _shard()
    resp = shard.handle(Request("warp"))
    assert not resp.ok and "warp" in resp.error
    resp = shard.handle(Request("lookup", ()))   # missing args -> error
    assert not resp.ok and isinstance(resp, Response)
    shard.close()
    shard.close()


def test_shard_roundtrip_and_stats():
    shard = _shard()
    arr = np.arange(12, dtype=np.float32)
    ok = shard.handle(Request("insert",
                              (5, "decoded", arr, arr.nbytes, False)))
    assert ok.ok and ok.value
    form, value, tier = shard.handle(Request("lookup", (5,))).value
    assert form == "decoded" and tier == "dram"
    assert np.array_equal(value, arr)
    stats = shard.handle(Request("stats", ())).value
    assert stats["shard"] == 0 and stats["entries"] == 1
    assert stats["bytes_used"] == arr.nbytes
    shard.close()


# ----------------------------------------------------------------------
# ShardedCache over the sim transport
def test_sharded_cache_surface_matches_local():
    c = ShardedCache(400_000, SPLIT, shards=3, seed=0)
    arr = np.arange(24, dtype=np.float32)
    for k in range(12):
        assert c.insert(k, "decoded", arr, arr.nbytes)
    assert c.form_of(3) == "decoded" and c.form_of(99) is None
    assert c.contains("decoded", 3) and not c.contains("encoded", 3)
    assert c.contains_many("decoded", range(12)) == [True] * 12
    assert c.serving_forms([3, 99]) == ["decoded", None]
    form, value, tier = c.lookup_tiered(3)
    assert form == "decoded" and tier == "dram"
    assert np.array_equal(value, arr)
    assert c.total_capacity("decoded") > 0
    assert sum(c.total_capacity(f) for f in FORMS) <= 400_000
    assert c.bytes_used() == 12 * arr.nbytes
    status = c.status_array(16)
    assert (status[:12] > 0).all() and (status[12:] == 0).all()
    assert c.evict(3, "decoded") and c.form_of(3) is None
    assert c.hit_rate() > 0
    v0 = c.version
    c.resize((0.1, 0.45, 0.45))
    assert c.split == (0.1, 0.45, 0.45)
    assert c.version >= v0
    c.close()
    c.close()       # idempotent


def test_sharded_cache_piggybacks_evictions():
    # chain-terminal evictions (spill overflow) must piggyback across
    # the transport exactly like the local cache's take_evicted; a tiny
    # spill level under an LRU DRAM tier guarantees overflow
    root = tempfile.mkdtemp(prefix="seneca-piggyback-")
    pol = {"encoded": "lru", "decoded": "lru", "augmented": "lru"}
    c = ShardedCache(3_000, SPLIT, evict_policies=pol,
                     spill_bytes=2_000, spill_dir=root, spill_split=SPLIT,
                     shards=2, seed=0)
    for k in range(64):
        c.insert(k, "decoded", np.full(64, k, np.uint8), 64)
    assert c.has_pending_evicted()
    dropped = set(c.take_evicted())
    assert dropped and not c.has_pending_evicted()
    # every piggybacked key is really gone from its owning shard
    assert not any(c.contains_many("decoded", sorted(dropped)))
    c.close()
    os.rmdir(root)


def test_sharded_cache_spill_subdirs_cleaned():
    root = tempfile.mkdtemp(prefix="seneca-shard-spill-")
    pol = {"encoded": "lru", "decoded": "lru", "augmented": "lru"}
    c = ShardedCache(4_000, SPLIT, evict_policies=pol,
                     spill_bytes=200_000, spill_dir=root,
                     spill_split=SPLIT, shards=2, seed=0)
    assert c.has_spill
    for k in range(64):
        c.insert(k, "decoded", np.full(64, k, np.uint8), 64)
    assert c.disk_bytes_used() > 0          # DRAM overflow demoted
    spill = c.spill_stats()
    assert spill and sum(d.get("disk_entries", 0)
                         for d in spill.values()) > 0
    c.close()
    assert os.listdir(root) == []            # per-shard subdirs removed
    os.rmdir(root)


def test_sharded_cache_needs_split_or_profiles():
    with pytest.raises(ValueError, match="split or profiles"):
        ShardedCache(1_000, None, shards=2)
    with pytest.raises(ValueError, match="shards"):
        ShardedCache(1_000, SPLIT, shards=0)


def test_sharded_produce_and_ingest_sim():
    ds = tiny(n=48)
    c = ShardedCache(2 * 48 * ds.augmented_bytes(), SPLIT, shards=2,
                     seed=0, dataset=ds)
    out = np.asarray(c.produce(7, epoch_tag=2))
    img = ds.decode(ds.encoded(7), 7)
    ref = augment_np(img, ds.crop_hw,
                     np.random.default_rng(produce_seed(2, 7)))
    assert np.array_equal(out, ref)
    assert c.ingest(range(48), epoch_tag=2) == 48
    ss = c.shard_stats()
    assert sum(s["produced"] for s in ss) == 49
    assert all(s["produced"] > 0 for s in ss)
    c.close()


# ----------------------------------------------------------------------
# server integration
def test_server_sharded_session_and_stats():
    ds = tiny(n=64)
    server = SenecaServer.for_dataset(ds, cache_frac=0.5, seed=0,
                                      shards=2)
    with server.open_session(batch_size=16) as sess:
        pipe = DSIPipeline(sess, RemoteStorage(ds), n_workers=2)
        for _ in range(6):       # > 1 epoch: admissions + shard lookups
            batch = pipe.next_batch()
            assert batch["images"].shape[0] == 16
        stats = sess.stats()
        pipe.stop()
    assert len(stats["shards"]) == 2
    assert {s["shard"] for s in stats["shards"]} == {0, 1}
    assert sum(s["entries"] for s in stats["shards"]) > 0
    server.close()
    server.close()      # idempotent


def test_virtual_clock_rejects_process_transport():
    ds = tiny(n=32)
    server = SenecaServer.for_dataset(ds, cache_frac=0.5, seed=0,
                                      shards=2)
    # the guard keys off the cache's transport tag — no need to spawn
    server.service.cache.transport_name = "process"
    with pytest.raises(ValueError, match="sim"):
        WorkloadRunner(server, RemoteStorage(ds), clock=VirtualClock())
    server.close()


def _sharded_workload_ids(shards: int, seed: int = 0):
    ds = tiny(n=64)
    server = SenecaServer.for_dataset(
        ds, cache_bytes=2 * ds.n_samples * ds.augmented_bytes(),
        split=SPLIT, seed=seed, shards=shards)
    runner = deterministic_runner(server, RemoteStorage(ds), seed=seed)
    res = runner.run([
        JobSpec("a", arrival_s=0.0, epochs=1, batch_size=16,
                gpu_rate=1000),
        JobSpec("b", arrival_s=0.05, epochs=1, batch_size=8,
                gpu_rate=500),
    ], timeout=120)
    ids = {j.spec.name: list(j.sample_ids) for j in res.jobs}
    server.close()
    return ids


def test_sharded_sim_runs_are_deterministic():
    """The tier-1 acceptance gate: the same trace on shards=1 (classic
    engine) and shards=2 (sim transport) yields identical per-job
    sample-id sequences, and shards=2 is reproducible run to run."""
    one = _sharded_workload_ids(1)
    two = _sharded_workload_ids(2)
    two_again = _sharded_workload_ids(2)
    assert two == two_again
    assert one == two
    assert all(len(v) == 64 for v in one.values())


# ----------------------------------------------------------------------
# process transport
def test_process_transport_roundtrip_and_close():
    ds = tiny(n=32)
    c = ShardedCache(2 * 32 * ds.augmented_bytes(), SPLIT, shards=2,
                     transport="process", seed=0, dataset=ds)
    xchg = c._xchg
    try:
        arr = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
        assert c.insert(40, "decoded", arr, arr.nbytes)
        form, value, tier = c.lookup_tiered(40)
        assert form == "decoded" and tier == "dram"
        assert np.array_equal(np.asarray(value), arr)
        out = np.asarray(c.produce(9, epoch_tag=3))
        img = ds.decode(ds.encoded(9), 9)
        ref = augment_np(img, ds.crop_hw,
                         np.random.default_rng(produce_seed(3, 9)))
        assert np.array_equal(out, ref)   # cross-process byte parity
        assert c.ingest(range(32), epoch_tag=1) == 32
    finally:
        c.close()
        c.close()
    assert not os.path.exists(xchg)


def test_process_transport_failed_start_cleans_up():
    before = set(glob.glob(os.path.join(tempfile.gettempdir(),
                                        "seneca-xchg-*")))
    with pytest.raises(Exception):
        # lambdas cannot pickle to a spawned shard: start must fail,
        # tear the fleet down, and leave no exchange dir behind
        ShardedCache(10_000, SPLIT, shards=2, transport="process",
                     dataset=lambda: None)
    after = set(glob.glob(os.path.join(tempfile.gettempdir(),
                                       "seneca-xchg-*")))
    assert after == before


# ----------------------------------------------------------------------
# close() idempotence on the classic engine (satellite)
def test_tiered_cache_close_idempotent_with_spill():
    root = tempfile.mkdtemp(prefix="seneca-close-")
    cache = TieredCache(4_000, SPLIT, spill_bytes=50_000, spill_dir=root,
                        spill_split=SPLIT)
    cache.insert(1, "decoded", np.zeros(900, np.uint8), 900)
    cache.insert(2, "decoded", np.zeros(900, np.uint8), 900)
    cache.close()
    assert not any(files for _p, _d, files in os.walk(root))
    cache.close()       # second close: no raise, no re-created files
    assert not any(files for _p, _d, files in os.walk(root))
    os.rmdir(root)
