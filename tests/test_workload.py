"""Live multi-job workload runner + deterministic-clock concurrency.

Covers the ISSUE-4 contract: the VirtualClock serializes participants in
``(wake_time, ticket)`` order and advances deterministically; two
virtual-clock runs of the same trace produce identical per-job sample-id
sequences and identical makespans; the live stack's hit rate agrees with
the :class:`DSISimulator` on the same 2-job trace (tying the runner to
the Fig. 8 model); arrivals/epoch accounting/cancellation behave; and
the private-server baseline mode works (the fig_live_makespan shape).
"""
import threading
import time

import numpy as np
import pytest

from repro.api import (AZURE_NC96, DSISimulator, DatasetProfile, JobSpec,
                       SENECA, SenecaServer, SimJob, VirtualClock,
                       WorkloadRunner)
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.workload.clock import RealClock


def _server(ds, **kw):
    kw.setdefault("cache_frac", 0.4)
    kw.setdefault("seed", 0)
    return SenecaServer.for_dataset(ds, **kw)


# ----------------------------------------------------------------------
# VirtualClock semantics
def test_virtual_clock_serializes_in_wake_order():
    clock = VirtualClock()
    t0, t1, t2 = clock.register(), clock.register(), clock.register()
    order = []
    lock = threading.Lock()

    def body(ticket, wakes):
        for w in wakes:
            now = clock.sleep_until(ticket, w)
            with lock:
                order.append((now, ticket))
        clock.unregister(ticket)

    # same wake time 1.0 for tickets 0 and 1 -> ticket order breaks the
    # tie; ticket 2 wakes earlier and again later
    threads = [threading.Thread(target=body, args=args) for args in
               ((t0, [1.0, 3.0]), (t1, [1.0, 2.0]), (t2, [0.5, 5.0]))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not any(t.is_alive() for t in threads)
    assert order == [(0.5, t2), (1.0, t0), (1.0, t1), (2.0, t1),
                     (3.0, t0), (5.0, t2)]
    assert clock.now() == 5.0


def test_virtual_clock_never_goes_backwards():
    clock = VirtualClock(start=10.0)
    t = clock.register()
    done = []

    def body():
        # asking to wake in the past clamps to the current virtual time
        done.append(clock.sleep_until(t, 3.0))
        clock.unregister(t)

    th = threading.Thread(target=body)
    th.start()
    th.join(timeout=10.0)
    assert done == [10.0]


def test_virtual_clock_unregistered_ticket_rejected():
    clock = VirtualClock()
    with pytest.raises(RuntimeError, match="not registered"):
        clock.sleep_until(99, 1.0)


def test_virtual_clock_interrupt_unblocks():
    clock = VirtualClock()
    t0, t1 = clock.register(), clock.register()   # t1 never sleeps
    stop = threading.Event()
    out = []

    def body():
        out.append(clock.sleep_until(t0, 1.0, interrupt=stop))
        clock.unregister(t0)

    th = threading.Thread(target=body)
    th.start()
    time.sleep(0.1)
    stop.set()
    th.join(timeout=10.0)
    assert not th.is_alive(), "interrupted sleep must not deadlock"
    clock.unregister(t1)


# ----------------------------------------------------------------------
# runner basics (real clock)
def test_runner_epochs_coverage_and_arrival_order():
    ds = tiny(n=96)
    server = _server(ds, use_ods=False)     # naive: exact epoch coverage
    runner = WorkloadRunner(server, RemoteStorage(ds))
    res = runner.run([
        JobSpec("a", arrival_s=0.0, epochs=2, batch_size=12,
                gpu_rate=4000, n_workers=2),
        JobSpec("b", arrival_s=0.2, epochs=1, batch_size=12,
                gpu_rate=4000, n_workers=2),
    ], timeout=120)
    server.close()
    assert res.ok
    a, b = res.job("a"), res.job("b")
    assert a.samples == 2 * 96 and a.epochs_completed == 2
    assert b.samples == 96 and b.epochs_completed == 1
    assert b.start_s >= 0.2 > a.start_s
    # naive sampler serves the epoch permutation exactly: each epoch
    # covers every sample once
    for job in (a, b):
        for e in range(job.epochs_completed):
            epoch_ids = job.sample_ids[e * 96:(e + 1) * 96]
            assert sorted(epoch_ids) == list(range(96))
    assert res.makespan >= max(a.end_s, b.end_s)
    assert res.stats["n_sessions"] == 0          # all sessions closed


def test_runner_gpu_rate_paces_consumption():
    ds = tiny(n=64)
    server = _server(ds)
    runner = WorkloadRunner(server, RemoteStorage(ds))
    # 64 samples at 160/s >= 0.4s even though production is instant
    res = runner.run([JobSpec("slow", epochs=1, batch_size=16,
                              gpu_rate=160, n_workers=2)], timeout=120)
    server.close()
    assert res.ok
    assert res.jobs[0].duration_s >= 0.35
    assert res.wall_s >= 0.35


def test_runner_cancel_joins_promptly():
    ds = tiny(n=256)
    server = _server(ds)
    runner = WorkloadRunner(server, RemoteStorage(ds), record_ids=False)
    trace = [JobSpec(f"j{i}", epochs=50, batch_size=16, gpu_rate=300,
                     n_workers=2) for i in range(2)]
    threading.Timer(0.4, runner.cancel).start()
    res = runner.run(trace, timeout=60, raise_on_error=False)
    server.close()
    assert all(j.cancelled for j in res.jobs)
    assert res.wall_s < 30.0
    assert not res.ok


def test_runner_validates_trace_and_construction():
    ds = tiny(n=32)
    server = _server(ds)
    storage = RemoteStorage(ds)
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadRunner(server, storage, server_factory=lambda s: server)
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadRunner(storage=storage)
    runner = WorkloadRunner(server, storage)
    with pytest.raises(ValueError, match="empty workload"):
        runner.run([])
    with pytest.raises(ValueError, match="duplicate job names"):
        runner.run([JobSpec("x"), JobSpec("x")])
    with pytest.raises(ValueError, match="epochs"):
        JobSpec("bad", epochs=0)
    with pytest.raises(ValueError, match="gpu_rate"):
        JobSpec("bad", gpu_rate=0.0)
    with pytest.raises(ValueError, match="unknown executor"):
        JobSpec("bad", executor="warp-speed")   # fails at spec time,
    #   not inside a job thread with a session already open
    # virtual clock rejects the stage-parallel executor up front
    vrunner = WorkloadRunner(server, storage, clock=VirtualClock())
    with pytest.raises(ValueError, match="per-sample"):
        vrunner.run([JobSpec("sp", executor="stage-parallel")])
    server.close()


def test_runner_job_error_surfaces_after_join():
    ds = tiny(n=64)
    server = _server(ds)

    class BrokenStorage(RemoteStorage):
        def fetch(self, sample_id):
            raise IOError("storage down")

    runner = WorkloadRunner(server, BrokenStorage(ds))
    with pytest.raises(RuntimeError, match="workload jobs failed"):
        runner.run([JobSpec("a", epochs=1, batch_size=8, n_workers=1)],
                   timeout=60)
    res = runner.run([JobSpec("a", epochs=1, batch_size=8, n_workers=1)],
                     timeout=60, raise_on_error=False)
    assert res.jobs[0].error is not None and not res.ok
    server.close()
    assert server.service.backend.n_jobs >= 1   # no crash on teardown


def test_server_run_workload_convenience():
    ds = tiny(n=64)
    server = _server(ds)
    # timeout/raise_on_error forward to run() (review finding: they
    # used to TypeError against the constructor)
    res = server.run_workload(
        [JobSpec("a", epochs=1, batch_size=16, n_workers=2)],
        RemoteStorage(ds), record_ids=False, timeout=120,
        raise_on_error=True)
    server.close()
    assert res.ok and res.total_samples == 64
    assert res.stats is not None


def test_pipeline_construction_failure_closes_session(monkeypatch):
    """If DSIPipeline construction raises after the session opened, the
    session must still close — a phantom job would inflate the eviction
    threshold and repartition triggers forever (review finding)."""
    import repro.workload.runner as runner_mod
    ds = tiny(n=64)
    server = _server(ds)

    def boom(*a, **kw):
        raise RuntimeError("pipeline ctor boom")

    monkeypatch.setattr(runner_mod, "DSIPipeline", boom)
    runner = WorkloadRunner(server, RemoteStorage(ds))
    res = runner.run([JobSpec("a", epochs=1, batch_size=8)],
                     timeout=60, raise_on_error=False)
    assert res.jobs[0].error is not None
    assert server.n_sessions == 0, "leaked session after ctor failure"
    server.close()


# ----------------------------------------------------------------------
# ISSUE-4 satellite: virtual-clock determinism
def _virtual_run(n=128, seed=0):
    ds = tiny(n=n)
    server = _server(ds, seed=seed)
    runner = WorkloadRunner(server, RemoteStorage(ds),
                            clock=VirtualClock(), seed=seed)
    res = runner.run([
        JobSpec("a", arrival_s=0.0, epochs=2, batch_size=16,
                gpu_rate=1000),
        JobSpec("b", arrival_s=0.05, epochs=2, batch_size=16,
                gpu_rate=500),
        JobSpec("c", arrival_s=0.10, epochs=1, batch_size=8,
                gpu_rate=2000),
    ], timeout=300)
    stats = res.stats
    server.close()
    return res, stats


def test_virtual_clock_runs_are_deterministic():
    """Two runs of the same trace: identical per-job sample-id sequences
    AND identical makespan (the non-flaky-concurrency guarantee)."""
    res1, stats1 = _virtual_run()
    res2, stats2 = _virtual_run()
    for j1, j2 in zip(res1.jobs, res2.jobs):
        assert j1.sample_ids == j2.sample_ids, j1.spec.name
        assert j1.epoch_ends == j2.epoch_ends, j1.spec.name
        assert j1.end_s == j2.end_s
    assert res1.makespan == res2.makespan
    assert stats1["ods_hit_rate"] == stats2["ods_hit_rate"]
    assert stats1["substitutions"] == stats2["substitutions"]
    assert res1.clock == "virtual"


def test_virtual_clock_deterministic_with_hbm_tier():
    """Determinism holds with the device cache tier enabled: HBM
    admission/promotion must not introduce ordering races into
    virtual-clock runs (same trace -> same ids, ends, makespan)."""
    def run():
        ds = tiny(n=128)
        server = _server(
            ds, device_cache_bytes=int(0.3 * 128 * ds.augmented_bytes()))
        runner = WorkloadRunner(server, RemoteStorage(ds),
                                clock=VirtualClock(), seed=0)
        res = runner.run([
            JobSpec("a", arrival_s=0.0, epochs=2, batch_size=16,
                    gpu_rate=1000),
            JobSpec("b", arrival_s=0.05, epochs=2, batch_size=16,
                    gpu_rate=500),
        ], timeout=300)
        stats = res.stats
        server.close()
        return res, stats

    res1, stats1 = run()
    res2, stats2 = run()
    for j1, j2 in zip(res1.jobs, res2.jobs):
        assert j1.sample_ids == j2.sample_ids, j1.spec.name
        assert j1.epoch_ends == j2.epoch_ends, j1.spec.name
        assert j1.end_s == j2.end_s
    assert res1.makespan == res2.makespan
    assert stats1["ods_hit_rate"] == stats2["ods_hit_rate"]


def test_virtual_clock_interleaving_respects_rates():
    """Faster-ingest jobs finish earlier; epoch ends are monotone; the
    makespan is the slowest job's end (all in virtual seconds)."""
    res, _stats = _virtual_run()
    a, b, c = res.job("a"), res.job("b"), res.job("c")
    assert res.ok
    # b ingests at half a's rate over the same 2 epochs: finishes last
    assert b.end_s == res.makespan > a.end_s
    for j in res.jobs:
        assert j.epoch_ends == sorted(j.epoch_ends)
        assert j.samples == j.spec.epochs * 128
    # virtual makespan is pacing-determined: 2 epochs * 128 / 500 + 0.05
    assert b.end_s == pytest.approx(0.05 + 256 / 500, abs=1e-9)


# ----------------------------------------------------------------------
# ISSUE-4 satellite: cross-validation against the fluid simulator
def test_live_virtual_run_matches_simulator_hit_rate():
    """WorkloadRunner (virtual clock) and DSISimulator on the same 2-job
    trace agree on the serve-level cache hit rate — the live stack is
    tied to the same model Fig. 8 validates."""
    n, batch, epochs, rate = 256, 16, 2, 2000
    ds = tiny(n=n)
    cache_bytes = int(0.35 * n * ds.augmented_bytes())

    server = SenecaServer.for_dataset(ds, cache_bytes=cache_bytes, seed=0)
    runner = WorkloadRunner(server, RemoteStorage(ds),
                            clock=VirtualClock(), record_ids=False)
    res = runner.run([JobSpec("a", 0.0, epochs, batch, rate),
                      JobSpec("b", 0.0, epochs, batch, rate)],
                     timeout=300)
    # serve-level hit rate: fraction of pipeline lookups answered by any
    # cache tier (the simulator's hits/misses count the same event)
    hit_rates = res.stats["telemetry"]["hit_rates"]
    live_hit = 1.0 - hit_rates.get("storage", 0.0)
    server.close()
    assert res.ok

    profile = DatasetProfile(ds.name, n, ds.mean_encoded_bytes,
                             decoded_bytes=ds.decoded_bytes(),
                             augmented_bytes=ds.augmented_bytes())
    sim = DSISimulator(AZURE_NC96, profile, SENECA,
                       cache_bytes=cache_bytes, seed=0)
    sim_res = sim.run([SimJob(0, gpu_rate=rate, batch_size=batch,
                              epochs=epochs),
                       SimJob(1, gpu_rate=rate, batch_size=batch,
                              epochs=epochs)])
    # both sides are deterministic (virtual clock / seeded sim): the
    # tolerance absorbs modelling differences (refill policy, admission
    # timing), not run-to-run noise
    assert live_hit == pytest.approx(sim_res.hit_rate, abs=0.12), \
        f"live={live_hit:.3f} sim={sim_res.hit_rate:.3f}"
    assert live_hit > 0.5 and sim_res.hit_rate > 0.5


# ----------------------------------------------------------------------
# private-server baseline mode (the fig_live_makespan shape)
def test_private_server_factory_mode():
    ds = tiny(n=64)
    storage = RemoteStorage(ds)
    made = []

    def factory(spec):
        srv = _server(ds, use_ods=False, split=(1.0, 0.0, 0.0),
                      eviction="lru")
        made.append(srv)
        return srv

    runner = WorkloadRunner(server_factory=factory, storage=storage)
    res = runner.run([JobSpec("a", epochs=1, batch_size=16, n_workers=2),
                      JobSpec("b", epochs=1, batch_size=16, n_workers=2)],
                     timeout=120)
    assert res.ok and len(made) == 2
    assert res.stats is None                    # no shared server
    for j in res.jobs:
        assert j.stats is not None              # per-job private stats
        assert j.stats["n_sessions"] == 0
    # private servers see only their own job
    assert all(s.n_sessions == 0 for s in made)


def test_real_clock_sleep_until_interruptible():
    clock = RealClock()
    t = clock.register()
    stop = threading.Event()
    stop.set()
    t0 = time.monotonic()
    clock.sleep_until(t, time.monotonic() + 5.0, interrupt=stop)
    assert time.monotonic() - t0 < 1.0
    clock.unregister(t)


def test_pipeline_consume_hook_fires_per_batch():
    from repro.data.pipeline import DSIPipeline
    ds = tiny(n=32)
    server = _server(ds)
    calls = []
    pipe = DSIPipeline(server.open_session(batch_size=8),
                       RemoteStorage(ds), n_workers=1,
                       consume_hook=lambda b: calls.append(
                           b["ids"].tolist()))
    got = [pipe.next_batch()["ids"].tolist() for _ in range(3)]
    assert calls == got                  # hook sees every emitted batch
    pipe.stop()
    server.close()
    assert np.asarray(got).shape == (3, 8)


def test_pipeline_consume_hook_fires_on_stage_parallel_get():
    """The hook contract holds on the stage-parallel consumer path too:
    get() fires it once per retrieved batch (review finding: it used to
    bypass the hook entirely)."""
    from repro.data.pipeline import DSIPipeline
    ds = tiny(n=48)
    server = _server(ds)
    calls = []
    pipe = DSIPipeline(server.open_session(batch_size=8),
                       RemoteStorage(ds), n_workers=2,
                       executor="stage-parallel",
                       consume_hook=lambda b: calls.append(
                           b["ids"].tolist()))
    got = [pipe.get(timeout=60.0)["ids"].tolist() for _ in range(3)]
    assert calls == got
    pipe.stop()
    server.close()


def test_non_dividing_batch_size_exact_accounting():
    """batch_size that does not divide the dataset: the runner targets
    the sampler's real whole-batch epoch pass — no final-batch sample
    overshoot, epoch accounting exact (review finding)."""
    ds = tiny(n=96)
    server = _server(ds, use_ods=False)
    runner = WorkloadRunner(server, RemoteStorage(ds))
    res = runner.run([JobSpec("odd", epochs=2, batch_size=20,
                              gpu_rate=5_000, n_workers=2)], timeout=120)
    server.close()
    job = res.jobs[0]
    epoch_size = (96 // 20) * 20                     # 80
    assert job.samples == 2 * epoch_size             # not 2*96 rounded up
    assert job.batches == 2 * epoch_size // 20
    assert job.epochs_completed == 2
    # batch_size larger than the dataset is rejected loudly
    server2 = _server(ds)
    runner2 = WorkloadRunner(server2, RemoteStorage(ds))
    with pytest.raises(RuntimeError, match="exceeds the dataset"):
        runner2.run([JobSpec("huge", batch_size=200)], timeout=60)
    server2.close()


def test_timeout_expiry_raises_instead_of_truncating():
    """A run() host-timeout must not return truncated results as if
    complete (review finding): it raises under raise_on_error, and the
    inspectable result carries timed_out=True otherwise."""
    ds = tiny(n=256)
    server = _server(ds)
    storage = RemoteStorage(ds)
    trace = [JobSpec("long", epochs=100, batch_size=16, gpu_rate=200,
                     n_workers=2)]
    with pytest.raises(RuntimeError, match="timed out"):
        WorkloadRunner(server, storage,
                       record_ids=False).run(trace, timeout=0.4)
    res = WorkloadRunner(server, storage, record_ids=False).run(
        trace, timeout=0.4, raise_on_error=False)
    assert res.timed_out and res.jobs[0].cancelled and not res.ok
    server.close()
