"""Device decode parity: the Pallas counter-hash decode kernel and the
fused decode+augment op against the host ``SyntheticDataset`` oracle.

The decode half must be *byte-identical* (uint8 out, integer hash all the
way).  The fused op must equal the decode-then-``augment_batch_seeded``
composition bitwise per sample — it runs the exact same float pipeline on
the same crop windows, just without materializing the decoded image.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.api  # noqa: F401  (break the pipeline<->api import cycle)
from repro.data.pipeline import fused_decode_seed as pipeline_fds
from repro.data.synthetic import DecodeHeavyDataset, SyntheticDataset
from repro.kernels.augment.ops import (augment_batch_seeded,
                                       decode_augment_batch_seeded)
from repro.kernels.decode.ops import (decode_batch, decode_batch_ref,
                                      decode_params, fused_decode_seed)

HW = (48, 40)
CROP = (32, 24)


def _ds(seed: int) -> SyntheticDataset:
    return SyntheticDataset("t", 256, 2048, image_hw=HW, crop_hw=CROP,
                            seed=seed)


# ------------------------------------------------------------- decode
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       sids=st.lists(st.integers(0, 255), min_size=1, max_size=5))
def test_decode_batch_matches_dataset(seed, sids):
    """Kernel decode is byte-identical to SyntheticDataset.decode for
    random (dataset seed, sample id, payload) triples."""
    ds = _ds(seed)
    payloads = [ds.encoded(s) for s in sids]
    out = decode_batch(payloads, sids, seed=seed, image_hw=HW)
    ref = np.stack([ds.decode(p, s) for p, s in zip(payloads, sids)])
    assert out.dtype == np.uint8
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("interpret", [True, None])
def test_decode_batch_interpret_paths(interpret):
    """Both the forced-interpret and auto-selected paths decode
    byte-identically (on CPU CI "auto" resolves to interpret via the
    cached module-level probe, but the contract must hold either way)."""
    ds = _ds(7)
    sids = [0, 3, 17, 101]
    payloads = [ds.encoded(s) for s in sids]
    out = decode_batch(payloads, sids, seed=7, image_hw=HW,
                       interpret=interpret)
    ref = np.stack([ds.decode(p, s) for p, s in zip(payloads, sids)])
    np.testing.assert_array_equal(out, ref)


def test_decode_params_match_dataset_derivation():
    ds = _ds(99)
    sids = [0, 1, 42, 200]
    payloads = [ds.encoded(s) for s in sids]
    bases, mixes = decode_params(99, sids, payloads)
    assert list(bases) == [ds.decode_base_seed(s) for s in sids]
    assert list(mixes) == [ds.decode_head_mix(p) for p in payloads]


def test_decode_jnp_oracle_agrees_with_kernel():
    ds = _ds(5)
    sids = [2, 9, 31]
    payloads = [ds.encoded(s) for s in sids]
    out = decode_batch(payloads, sids, seed=5, image_hw=HW)
    ref = np.asarray(decode_batch_ref(payloads, sids, seed=5, image_hw=HW))
    np.testing.assert_array_equal(out, ref)


# ------------------------------------------------------- fused op
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       sids=st.lists(st.integers(0, 255), min_size=1, max_size=4),
       epoch=st.integers(0, 3))
def test_fused_equals_decode_then_augment(seed, sids, epoch):
    """decode_augment_batch_seeded == decode + augment_batch_seeded,
    bitwise per sample, for random (seed, ids, epoch) draws."""
    ds = _ds(seed)
    payloads = [ds.encoded(s) for s in sids]
    aug_seeds = np.asarray([(epoch * 1_000_003 + s) & 0x7FFFFFFF
                            for s in sids], np.int64)
    fused = np.asarray(decode_augment_batch_seeded(
        payloads, sids, aug_seeds, ds_seed=seed, image_hw=HW,
        crop_h=CROP[0], crop_w=CROP[1]))
    imgs = np.stack([ds.decode(p, s) for p, s in zip(payloads, sids)])
    ref = augment_batch_seeded(imgs, aug_seeds, *CROP)
    np.testing.assert_array_equal(fused, ref)


def test_fused_bucket_padding_is_invisible():
    """Power-of-two padding (B=3 -> 4) and an exact bucket=B trace give
    the same rows — padding must never leak into the sliced output."""
    ds = _ds(11)
    sids = [5, 6, 7]
    payloads = [ds.encoded(s) for s in sids]
    seeds = np.asarray([s * 13 + 1 for s in sids], np.int64)
    kw = dict(ds_seed=11, image_hw=HW, crop_h=CROP[0], crop_w=CROP[1])
    padded = np.asarray(decode_augment_batch_seeded(
        payloads, sids, seeds, **kw))
    exact = np.asarray(decode_augment_batch_seeded(
        payloads, sids, seeds, bucket=len(sids), **kw))
    assert padded.shape[0] == len(sids)
    np.testing.assert_array_equal(padded, exact)


def test_fused_output_stays_on_device():
    import jax
    ds = _ds(1)
    out = decode_augment_batch_seeded(
        [ds.encoded(0)], [0], np.asarray([3], np.int64), ds_seed=1,
        image_hw=HW, crop_h=CROP[0], crop_w=CROP[1])
    assert isinstance(out, jax.Array)


# ------------------------------------------------- fused-decode gating
def test_fused_decode_seed_gating():
    base = _ds(42)
    assert fused_decode_seed(base) == 42
    heavy = DecodeHeavyDataset("h", 16, 1024, seed=42)
    assert fused_decode_seed(heavy) is None
    # the pipeline re-exports the same gate (lazy wrapper)
    assert pipeline_fds(base) == 42
    assert pipeline_fds(heavy) is None
