"""End-to-end mechanistic pipeline: real threads, cache, ODS, decode."""
import numpy as np
import pytest

from repro.core.perf_model import (AZURE_NC96, GB, DatasetProfile,
                                   JobProfile)
from repro.core.seneca import SenecaConfig, SenecaService
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny


def _service(ds, cache_frac=0.4, use_ods=True, split=None):
    profile = DatasetProfile(ds.name, ds.n_samples, ds.mean_encoded_bytes,
                             decoded_bytes=ds.decoded_bytes(),
                             augmented_bytes=ds.augmented_bytes())
    cache_bytes = int(cache_frac * ds.n_samples * ds.augmented_bytes())
    return SenecaService(SenecaConfig(
        cache_bytes=cache_bytes, hardware=AZURE_NC96, dataset=profile,
        use_ods=use_ods, split=split, seed=1))


def test_pipeline_produces_normalized_batches():
    ds = tiny(n=256)
    svc = _service(ds)
    pipe = DSIPipeline(0, svc, RemoteStorage(ds), batch_size=16,
                       n_workers=2)
    b = pipe.next_batch()
    assert b["images"].shape == (16, *ds.crop_hw, 3)
    assert b["labels"].shape == (16,)
    assert abs(float(b["images"].mean())) < 2.0      # normalized
    assert np.isfinite(b["images"]).all()
    pipe.stop()


def test_two_jobs_share_cache_and_keep_epoch_semantics():
    ds = tiny(n=240)
    svc = _service(ds)
    storage = RemoteStorage(ds)
    p0 = DSIPipeline(0, svc, storage, batch_size=20, n_workers=2)
    p1 = DSIPipeline(1, svc, storage, batch_size=20, n_workers=2)
    seen = {0: [], 1: []}
    for _ in range(ds.n_samples // 20):
        for jid, p in ((0, p0), (1, p1)):
            ids, _ = svc.next_batch_ids(jid)
            seen[jid].extend(ids.tolist())
    for jid in (0, 1):
        assert sorted(seen[jid]) == list(range(ds.n_samples)), \
            f"job {jid} must see every sample exactly once per epoch"
    p0.stop()
    p1.stop()


def test_ods_improves_hit_rate_vs_mdp_only():
    ds = tiny(n=400)
    results = {}
    for use_ods in (False, True):
        svc = _service(ds, cache_frac=0.3, use_ods=use_ods,
                       split=(0.0, 0.0, 1.0))
        storage = RemoteStorage(ds)
        pipes = [DSIPipeline(j, svc, storage, batch_size=20, n_workers=2)
                 for j in (0, 1)]
        for _ in range(2 * ds.n_samples // 20):
            for p in pipes:
                p.next_batch()
        results[use_ods] = svc.ods.hit_rate()
        for p in pipes:
            p.stop()
    assert results[True] > results[False] + 0.02, results


def test_deterministic_samples():
    ds = tiny(n=64)
    a = ds.encoded(7)
    b = ds.encoded(7)
    assert a == b
    assert ds.encoded(8) != a
    img = ds.decode(a, 7)
    assert img.shape == (*ds.image_hw, 3) and img.dtype == np.uint8


def test_storage_bandwidth_budget():
    import time
    ds = tiny(n=16, mean_bytes=50_000)
    storage = RemoteStorage(ds, bandwidth=1e6)   # 1 MB/s
    t0 = time.monotonic()
    storage.fetch(0)
    storage.fetch(1)
    dt = time.monotonic() - t0
    expected = (ds.encoded_size(0) + ds.encoded_size(1)) / 1e6
    assert dt >= expected * 0.5
