"""End-to-end mechanistic pipeline: real threads, cache, ODS, decode —
driven through the repro.api session facade."""
import numpy as np
import pytest

from repro.api import AZURE_NC96, SenecaServer
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny


def _server(ds, cache_frac=0.4, use_ods=True, split=None, **kw):
    return SenecaServer.for_dataset(ds, cache_frac=cache_frac,
                                    hardware=AZURE_NC96, use_ods=use_ods,
                                    split=split, seed=1, **kw)


def test_pipeline_produces_normalized_batches():
    ds = tiny(n=256)
    server = _server(ds)
    pipe = DSIPipeline(server.open_session(batch_size=16),
                       RemoteStorage(ds), n_workers=2)
    b = pipe.next_batch()
    assert b["images"].shape == (16, *ds.crop_hw, 3)
    assert b["labels"].shape == (16,)
    assert abs(float(b["images"].mean())) < 2.0      # normalized
    assert np.isfinite(b["images"]).all()
    pipe.stop()


def test_two_jobs_share_cache_and_keep_epoch_semantics():
    ds = tiny(n=240)
    server = _server(ds)
    storage = RemoteStorage(ds)
    sessions = [server.open_session(batch_size=20) for _ in range(2)]
    pipes = [DSIPipeline(s, storage, n_workers=2) for s in sessions]
    seen = {0: [], 1: []}
    for _ in range(ds.n_samples // 20):
        for jid, s in enumerate(sessions):
            ids, _ = s.next_batch_ids()
            seen[jid].extend(ids.tolist())
    for jid in (0, 1):
        assert sorted(seen[jid]) == list(range(ds.n_samples)), \
            f"job {jid} must see every sample exactly once per epoch"
    for p in pipes:
        p.stop()


def test_ods_improves_hit_rate_vs_mdp_only():
    ds = tiny(n=400)
    results = {}
    for use_ods in (False, True):
        server = _server(ds, cache_frac=0.3, use_ods=use_ods,
                         split=(0.0, 0.0, 1.0))
        storage = RemoteStorage(ds)
        pipes = [DSIPipeline(server.open_session(batch_size=20), storage,
                             n_workers=2) for _ in range(2)]
        for _ in range(2 * ds.n_samples // 20):
            for p in pipes:
                p.next_batch()
        results[use_ods] = server.stats()["ods_hit_rate"]
        for p in pipes:
            p.stop()
    assert results[True] > results[False] + 0.02, results


def test_legacy_service_entry_point_still_works():
    """The deprecated core.seneca + (job_id, service, ...) call style keeps
    running behind the facade shims."""
    import sys
    ds = tiny(n=128)
    sys.modules.pop("repro.core.seneca", None)   # force re-import warning
    with pytest.deprecated_call():
        from repro.core.seneca import SenecaConfig, SenecaService
    from repro.api import DatasetProfile
    svc = SenecaService(SenecaConfig(
        cache_bytes=int(0.4 * ds.n_samples * ds.augmented_bytes()),
        hardware=AZURE_NC96,
        dataset=DatasetProfile(ds.name, ds.n_samples,
                               ds.mean_encoded_bytes,
                               decoded_bytes=ds.decoded_bytes(),
                               augmented_bytes=ds.augmented_bytes()),
        seed=1))
    with pytest.deprecated_call():
        pipe = DSIPipeline(0, svc, RemoteStorage(ds), batch_size=16,
                           n_workers=2)
    b = pipe.next_batch()
    assert b["images"].shape[0] == 16
    ids, forms = svc.next_batch_ids(0)         # raw job_id API still live
    assert len(ids) == 16
    pipe.stop()
    with pytest.deprecated_call():             # positional batch_size form
        pipe2 = DSIPipeline(1, svc, RemoteStorage(ds), 8, n_workers=2)
    assert pipe2.next_batch()["images"].shape[0] == 8
    pipe2.stop()


def test_deterministic_samples():
    ds = tiny(n=64)
    a = ds.encoded(7)
    b = ds.encoded(7)
    assert a == b
    assert ds.encoded(8) != a
    img = ds.decode(a, 7)
    assert img.shape == (*ds.image_hw, 3) and img.dtype == np.uint8


def test_storage_bandwidth_budget():
    import time
    ds = tiny(n=16, mean_bytes=50_000)
    storage = RemoteStorage(ds, bandwidth=1e6)   # 1 MB/s
    t0 = time.monotonic()
    storage.fetch(0)
    storage.fetch(1)
    dt = time.monotonic() - t0
    expected = (ds.encoded_size(0) + ds.encoded_size(1)) / 1e6
    assert dt >= expected * 0.5
