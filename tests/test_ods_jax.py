"""Jittable ODS twin: same invariants under jit."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ods_jax


def test_invariants_under_jit():
    N, B = 256, 16
    state = ods_jax.create(N)
    state = state._replace(
        status=state.status.at[jnp.arange(0, N, 3)].set(3))
    rng = jax.random.key(0)
    seen = set()
    for i in range(2 * (N // B)):
        rng, sub = jax.random.split(rng)
        req = jnp.arange(i * B, i * B + B) % N
        state, batch, ev = ods_jax.substitute_jit(state, req, sub, 2)
        b = np.asarray(batch)
        assert len(set(b.tolist())) == B
        assert not (seen & set(b.tolist()))
        seen |= set(b.tolist())
        if len(seen) == N:
            seen = set()


def test_prefers_cached_unseen():
    N, B = 128, 8
    state = ods_jax.create(N)
    state = state._replace(status=state.status.at[:64].set(1))
    rng = jax.random.key(1)
    req = jnp.arange(64, 64 + B)              # all uncached
    state, batch, _ = ods_jax.substitute_jit(state, req, rng, 1)
    assert np.all(np.asarray(state.status)[np.asarray(batch)] == 1)


def test_eviction_mask_threshold():
    N, B = 64, 8
    state = ods_jax.create(N)
    state = state._replace(status=state.status.at[:16].set(3))
    rng = jax.random.key(2)
    req = jnp.arange(0, B)                    # cached augmented directs
    state, batch, ev = ods_jax.substitute_jit(state, req, rng, 1)
    # threshold 1 job: every served augmented sample evicts immediately
    served_aug = np.asarray(batch)[np.asarray(batch) < 16]
    assert np.asarray(ev)[served_aug].all()
    assert np.all(np.asarray(state.status)[served_aug] == 0)
