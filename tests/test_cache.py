"""Tiered cache store: byte accounting, policies, lookup order."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cache.store import CachePartition, TieredCache


@settings(max_examples=25, deadline=None)
@given(cap=st.integers(100, 10_000),
       ops=st.lists(st.tuples(st.integers(0, 50), st.integers(1, 2_000)),
                    min_size=1, max_size=60),
       policy=st.sampled_from(["none", "lru"]))
def test_capacity_never_exceeded(cap, ops, policy):
    part = CachePartition(cap, policy)
    for key, size in ops:
        part.put(key, b"x", size)
        assert part.stats.bytes_used <= cap
    # accounting consistent with contents
    assert part.stats.bytes_used == sum(part._sizes.values())


def test_no_evict_rejects_when_full():
    part = CachePartition(100, "none")
    assert part.put(1, "a", 60) == []
    part.put(2, "b", 60)
    assert 2 not in part                       # rejected, MINIO-style
    assert 1 in part


def test_lru_evicts_oldest():
    part = CachePartition(100, "lru")
    part.put(1, "a", 50)
    part.put(2, "b", 50)
    part.get(1)                                # 1 becomes MRU
    part.put(3, "c", 50)
    assert 2 not in part and 1 in part and 3 in part


def test_tiered_lookup_most_processed_first():
    c = TieredCache(3000, (0.34, 0.33, 0.33))
    c.insert(7, "encoded", b"e", 10)
    c.insert(7, "augmented", b"a", 10)
    form, val = c.lookup(7)
    assert form == "augmented"


def test_lookup_counts_one_miss_per_failed_lookup():
    """A key absent from every partition is exactly ONE miss — the seed
    never counted it at all (lookup probed `key in part` before get), so
    hit_rate() was inflated."""
    c = TieredCache(3000, (0.34, 0.33, 0.33))
    assert c.lookup(7) == (None, None)
    assert c.lookup_misses == 1
    assert c.hit_rate() == 0.0
    c.insert(7, "encoded", b"e", 10)
    form, _ = c.lookup(7)
    assert form == "encoded"
    # one hit, one miss — not one hit, zero misses
    assert c.hit_rate() == 0.5
    c.lookup(8)
    c.lookup(9)
    assert c.lookup_misses == 3
    assert abs(c.hit_rate() - 0.25) < 1e-9


def test_gated_insert_capacity_under_lock():
    """insert_gated evaluates the admission policy's capacity vote under
    the cache lock, atomically with the put."""
    from repro.api.policies import CapacityAdmission
    c = TieredCache(300, (1.0, 0.0, 0.0))
    pol = CapacityAdmission()
    assert c.insert_gated(1, "encoded", b"a", 200, pol)
    assert not c.insert_gated(2, "encoded", b"b", 200, pol)   # would overflow
    assert 2 not in c.parts["encoded"]
    # zero-capacity partitions always refuse
    assert not c.insert_gated(3, "decoded", b"c", 1, pol)


def test_status_array_roundtrip():
    c = TieredCache(3000, (0.34, 0.33, 0.33))
    c.insert(1, "encoded", b"", 10)
    c.insert(2, "decoded", b"", 10)
    c.insert(3, "augmented", b"", 10)
    s = c.status_array(5)
    assert list(s) == [0, 1, 2, 3, 0]


def test_partition_split_respects_mdp():
    c = TieredCache(1000, (0.5, 0.3, 0.2))
    assert c.parts["encoded"].capacity == 500
    assert c.parts["decoded"].capacity == 300
    assert c.parts["augmented"].capacity == 200
