"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.augment.kernel import augment
from repro.kernels.augment.ref import augment_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import flash_mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


# ---------------------------------------------------------------- augment
@pytest.mark.parametrize("hw,crop", [((32, 32), (24, 24)),
                                     ((64, 48), (56, 40)),
                                     ((128, 128), (112, 112))])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_augment_sweep(hw, crop, dtype):
    B = 3
    rng = jax.random.key(hash((hw, crop)) % 2**31)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    imgs = jax.random.randint(k1, (B, *hw, 3), 0, 256,
                              jnp.int32).astype(jnp.uint8)
    tops = jax.random.randint(k2, (B,), 0, hw[0] - crop[0] + 1, jnp.int32)
    lefts = jax.random.randint(k3, (B,), 0, hw[1] - crop[1] + 1, jnp.int32)
    flips = jax.random.bernoulli(k4, 0.5, (B,)).astype(jnp.int32)
    out_k = augment(imgs, tops, lefts, flips, crop_h=crop[0],
                    crop_w=crop[1], out_dtype=dtype)
    out_r = augment_ref(imgs, tops, lefts, flips.astype(bool), *crop,
                        out_dtype=dtype)
    # last-ulp fp32 difference: scalar-per-channel vs broadcast normalize
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=2e-6)
    assert out_k.dtype == dtype


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("S,hd,qb", [(128, 32, 64), (256, 64, 128),
                                     (192, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(S, hd, qb, dtype, causal):
    B, H = 2, 2
    rng = jax.random.key(S + hd)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd), jnp.float32).astype(
        dtype) for kk in jax.random.split(rng, 3))
    o_k = flash_attention(q, k, v, causal=causal, q_block=qb, k_block=qb)
    o_r = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol,
                               rtol=tol)


def test_flash_mha_gqa_expansion():
    B, S, H, K, hd = 2, 128, 8, 2, 32
    rng = jax.random.key(0)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_mha(q, k, v, causal=True)
    kf = jnp.repeat(k, H // K, 2)
    vf = jnp.repeat(v, H // K, 2)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(kf, 1, 2),
                        jnp.swapaxes(vf, 1, 2), causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(
        jnp.swapaxes(ref, 1, 2)), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("S,chunk,P,N", [(64, 16, 8, 16), (128, 32, 16, 32),
                                         (96, 32, 32, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(S, chunk, P, N, dtype):
    B, nh = 2, 3
    rng = jax.random.key(S * N)
    ks = jax.random.split(rng, 5)
    x = (jax.random.normal(ks[0], (B, S, nh, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, N)) * 0.5).astype(dtype)
    y_k, h_k = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_r, h_r = ssd_ref(x, dt, A, Bm, Cm)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               atol=tol, rtol=tol)


def test_ssd_kernel_matches_model_core():
    """The model's XLA SSD path and the Pallas kernel agree."""
    from repro.models.ssm import _ssd_core
    B, S, nh, P, N = 1, 64, 2, 8, 16
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, S, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y_k, h_k = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    y_m, h_m = _ssd_core(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               atol=1e-4, rtol=1e-4)
