"""Per-op collective attribution for one dry-run cell (hillclimb probe)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "src")
from repro.configs import registry
from repro.configs.base import SHAPES_BY_NAME
from repro.launch import dryrun
from repro.roofline import hlo_collectives as hc

arch, shape_name = sys.argv[1], sys.argv[2]
overrides = dict(kv.split("=",1) for kv in sys.argv[3:])
cfg = registry.get(arch)
shape = SHAPES_BY_NAME[shape_name]
par = registry.default_parallelism(cfg, shape)
if overrides:
    kw = {}
    for k, v in overrides.items():
        cur = getattr(par, k)
        kw[k] = (v in ("1","true")) if isinstance(cur, bool) else type(cur)(v)
    par = par.replace(**kw)

# monkeypatch analyze to collect per-line details
orig_wire = hc._wire_bytes
details = []
def analyze_verbose(text):
    comps = hc._segment(text)
    trip_of_cond = {c: max([int(x) for ln in ls for x in hc._CONST_RE.findall(ln)] or [1]) for c, ls in comps.items()}
    own, calls = {}, {}
    lines_of = {}
    for cname, lines in comps.items():
        ops, cl = [], []
        for line in lines:
            m = hc._OP_RE.search(line)
            if m:
                ops.append((m.group(2), hc._wire_bytes(line, m.group(2)), line.strip()[:140]))
            w = hc._WHILE_RE.search(line)
            if w:
                cl.append((w.group(2), max(trip_of_cond.get(w.group(1),1),1)))
            else:
                for callee in hc._CALL_RE.findall(line):
                    cl.append((callee, 1))
        own[cname] = ops; calls[cname] = cl
    called = {b for c in calls.values() for b,_ in c}
    roots = [c for c in comps if c not in called]
    entry = max(roots or comps, key=lambda c: len(comps[c]))
    def acc(cname, mult, depth=0):
        if depth > 12 or cname not in own: return
        for kind, wire, line in own[cname]:
            details.append((wire*mult, mult, kind, line))
        for callee, trips in calls[cname]:
            acc(callee, mult*trips, depth+1)
    acc(entry, 1.0)

import repro.launch.dryrun as dr
class FakeColl:
    pass
rec = None
# lower manually using dryrun internals
old_analyze = hc.analyze
def patched(text):
    analyze_verbose(text)
    return old_analyze(text)
hc.analyze = patched
rec = dr.lower_cell(arch, shape, multi_pod=False, parallel=par)
details.sort(reverse=True)
print(f"total wire: {sum(d[0] for d in details)/1e12:.2f} TB over {len(details)} op sites")
for wire, mult, kind, line in details[:15]:
    print(f"{wire/1e9:9.1f} GB  x{mult:6.0f} {kind:18s} {line[:110]}")
