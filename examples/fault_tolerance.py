"""Fault-tolerance demo: kill the trainer mid-run, watch it resume.

    PYTHONPATH=src python examples/fault_tolerance.py

Injects two simulated node failures; the ResilientTrainer restores the
latest atomic checkpoint each time and the final parameters are bit-exact
with an uninterrupted run (also covered by tests/test_checkpoint.py).
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ParallelismConfig
from repro.distributed.ft import FTConfig, ResilientTrainer
from repro.launch.train import lm_batch_source
from repro.models.model import build
from repro.train.optimizer import AdamW
from repro.train.step import build_train_step


def main() -> None:
    cfg = registry.get_reduced("deepseek-7b")
    model = build(cfg)
    opt = AdamW(lr=1e-3)
    step = jax.jit(build_train_step(model, ParallelismConfig(), opt))
    src = lm_batch_source(model, 8, 32)
    fixed = src()                              # deterministic batch stream

    def trainer(tag, injector=None):
        d = f"/tmp/ft_demo_{tag}"
        shutil.rmtree(d, ignore_errors=True)
        params = model.init(jax.random.key(0))
        return ResilientTrainer(
            step_fn=step, params=params, opt_state=opt.init(params),
            cfg=FTConfig(ckpt_dir=d, ckpt_every=10, max_restarts=5),
            batch_source=lambda: fixed, failure_injector=injector)

    clean = trainer("clean")
    clean.run(40)
    print(f"[ft] clean run:  40 steps, final loss "
          f"{clean.history[-1]['loss']:.4f}")

    failures = {17: True, 31: True}
    faulty = trainer("faulty", injector=lambda s: failures.pop(s, False))
    faulty.run(40)
    print(f"[ft] faulty run: 40 steps, {faulty.restarts} restarts, "
          f"final loss {faulty.history[-1]['loss']:.4f}")

    same = all(
        np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(clean.params),
                        jax.tree.leaves(faulty.params)))
    print(f"[ft] final params bit-identical after 2 failures: {same}")
    assert same


if __name__ == "__main__":
    main()
