"""Explore MDP: optimal cache splits across hardware and datasets.

    PYTHONPATH=src python examples/mdp_explorer.py [--cache-gb 400]

Prints the Table-6-style matrix plus a what-if sweep: how the optimal split
and predicted throughput move as the cache grows (the paper's space-time
trade-off, quantified).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from dataclasses import replace

from repro.core import mdp
from repro.core.perf_model import (DATASETS, EVAL_PROFILES, GB,
                                   IMAGENET_1K, AZURE_NC96)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-gb", type=float, default=0.0,
                    help="override cache size for the matrix")
    args = ap.parse_args()

    print(f"{'dataset':14s} " + " ".join(f"{h.name:>16s}"
                                         for h in EVAL_PROFILES))
    for ds in DATASETS:
        row = []
        for hw in EVAL_PROFILES:
            if args.cache_gb:
                hw = replace(hw, s_cache=args.cache_gb * GB)
            p = mdp.optimize(hw, ds)
            row.append(f"{p.label}({p.throughput:,.0f}/s)")
        print(f"{ds.name:14s} " + " ".join(f"{r:>16s}" for r in row))

    print("\ncache-size sweep (azure, imagenet-1k):")
    for gb in (64, 128, 256, 400, 800):
        hw = replace(AZURE_NC96, s_cache=gb * GB)
        p = mdp.optimize(hw, IMAGENET_1K)
        print(f"  {gb:4d} GB -> {p.label:>9s}  {p.throughput:8,.0f} "
              f"samples/s")


if __name__ == "__main__":
    main()
