"""The paper's headline scenario, for real: two training jobs share one
dataset through a Seneca service (MDP-partitioned cache + ODS sampling).

    PYTHONPATH=src python examples/concurrent_training.py

Trains two reduced ViT classifiers concurrently on the same synthetic image
dataset, each fed by its own threaded DSI pipeline over the SHARED cache,
and reports per-job throughput, the MDP partition, the ODS hit rate, and
the substitution count — then repeats with ODS disabled to show the delta
(Fig. 13/14 mechanics on live threads, not simulation).
"""
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ParallelismConfig
from repro.core.perf_model import AZURE_NC96, DatasetProfile
from repro.core.seneca import SenecaConfig, SenecaService
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.models.model import build
from repro.train.optimizer import AdamW
from repro.train.step import build_train_step


def run_once(use_ods: bool, steps: int = 15):
    ds = tiny(n=1024)
    storage = RemoteStorage(ds, bandwidth=300e6)
    svc = SenecaService(SenecaConfig(
        cache_bytes=int(0.35 * ds.n_samples * ds.augmented_bytes()),
        hardware=AZURE_NC96,
        dataset=DatasetProfile(ds.name, ds.n_samples,
                               ds.mean_encoded_bytes,
                               decoded_bytes=ds.decoded_bytes(),
                               augmented_bytes=ds.augmented_bytes()),
        use_ods=use_ods, seed=0))

    cfg = registry.get_reduced("vit-huge")
    model = build(cfg)
    opt = AdamW(lr=1e-3)
    step = jax.jit(build_train_step(model, ParallelismConfig(), opt))
    results = {}

    def job(jid: int):
        pipe = DSIPipeline(jid, svc, storage, batch_size=32, n_workers=3)
        params = model.init(jax.random.key(jid))
        state = opt.init(params)
        t0 = time.monotonic()
        for _ in range(steps):
            raw = pipe.next_batch()
            B = raw["images"].shape[0]
            flat = raw["images"].reshape(B, -1)
            T, D = cfg.frontend_tokens, cfg.d_model
            reps = -(-T * D // flat.shape[1])
            emb = np.tile(flat, (1, reps))[:, :T * D].reshape(B, T, D)
            batch = {"patch_embeds": jax.numpy.asarray(emb,
                                                       jax.numpy.bfloat16),
                     "labels": jax.numpy.asarray(
                         raw["labels"] % cfg.n_classes)}
            params, state, m = step(params, state, batch)
        dt = time.monotonic() - t0
        results[jid] = steps * 32 / dt
        pipe.stop()

    threads = [threading.Thread(target=job, args=(j,)) for j in (0, 1)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    return {
        "partition": svc.partition.label,
        "per_job_samples_s": {k: round(v, 1) for k, v in results.items()},
        "aggregate_samples_s": round(sum(results.values()), 1),
        "hit_rate": round(svc.ods.hit_rate(), 3),
        "substitutions": svc.ods.substitutions,
        "storage_fetches": storage.fetches,
        "wall_s": round(wall, 1),
    }


def main() -> None:
    print("[concurrent] with ODS:")
    with_ods = run_once(True)
    for k, v in with_ods.items():
        print(f"   {k}: {v}")
    print("[concurrent] without ODS (MDP-only):")
    without = run_once(False)
    for k, v in without.items():
        print(f"   {k}: {v}")
    print(f"[concurrent] ODS hit-rate delta: "
          f"{with_ods['hit_rate'] - without['hit_rate']:+.3f}; "
          f"storage fetches {without['storage_fetches']} -> "
          f"{with_ods['storage_fetches']}")


if __name__ == "__main__":
    main()
