"""The paper's headline scenario, for real: two training jobs share one
dataset through a Seneca server (MDP-partitioned cache + ODS sampling).

    PYTHONPATH=src python examples/concurrent_training.py

Trains two reduced ViT classifiers concurrently on the same synthetic image
dataset, each fed by its own threaded DSI pipeline over a *session* on the
SHARED ``repro.api.SenecaServer``, and reports per-job throughput, the MDP
partition, the ODS hit rate, and the substitution count — then repeats
with ODS disabled to show the delta (Fig. 13/14 mechanics on live threads,
not simulation).
"""
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.api import SenecaServer
from repro.configs import registry
from repro.configs.base import ParallelismConfig
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.models.model import build
from repro.train.optimizer import AdamW
from repro.train.step import build_train_step


def run_once(use_ods: bool, steps: int = 15, backend: str = "numpy"):
    ds = tiny(n=1024)
    storage = RemoteStorage(ds, bandwidth=300e6)
    server = SenecaServer.for_dataset(ds, cache_frac=0.35,
                                      use_ods=use_ods, seed=0,
                                      backend=backend)

    cfg = registry.get_reduced("vit-huge")
    model = build(cfg)
    opt = AdamW(lr=1e-3)
    step = jax.jit(build_train_step(model, ParallelismConfig(), opt))
    results = {}

    def job(jid: int):
        with server.open_session(batch_size=32) as sess:
            pipe = DSIPipeline(sess, storage, n_workers=3)
            params = model.init(jax.random.key(jid))
            state = opt.init(params)
            t0 = time.monotonic()
            for _ in range(steps):
                raw = pipe.next_batch()
                B = raw["images"].shape[0]
                flat = raw["images"].reshape(B, -1)
                T, D = cfg.frontend_tokens, cfg.d_model
                reps = -(-T * D // flat.shape[1])
                emb = np.tile(flat, (1, reps))[:, :T * D].reshape(B, T, D)
                batch = {"patch_embeds": jax.numpy.asarray(
                             emb, jax.numpy.bfloat16),
                         "labels": jax.numpy.asarray(
                             raw["labels"] % cfg.n_classes)}
                params, state, m = step(params, state, batch)
            dt = time.monotonic() - t0
            results[jid] = steps * 32 / dt
            pipe.stop()

    threads = [threading.Thread(target=job, args=(j,)) for j in (0, 1)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    stats = server.stats()
    return {
        "partition": stats["partition"],
        "per_job_samples_s": {k: round(v, 1) for k, v in results.items()},
        "aggregate_samples_s": round(sum(results.values()), 1),
        "hit_rate": round(stats["ods_hit_rate"], 3),
        "substitutions": stats["substitutions"],
        "storage_fetches": storage.fetches,
        "wall_s": round(wall, 1),
    }


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"))
    args = ap.parse_args()
    print(f"[concurrent] with ODS (backend={args.backend}):")
    with_ods = run_once(True, backend=args.backend)
    for k, v in with_ods.items():
        print(f"   {k}: {v}")
    print("[concurrent] without ODS (MDP-only):")
    without = run_once(False, backend=args.backend)
    for k, v in without.items():
        print(f"   {k}: {v}")
    print(f"[concurrent] ODS hit-rate delta: "
          f"{with_ods['hit_rate'] - without['hit_rate']:+.3f}; "
          f"storage fetches {without['storage_fetches']} -> "
          f"{with_ods['storage_fetches']}")


if __name__ == "__main__":
    main()
