"""Serve a small model with batched requests (continuous-batching lite).

    PYTHONPATH=src python examples/serve_llm.py --arch deepseek-7b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import registry
from repro.models.model import build
from repro.serve.step import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    server = Server(model, params, n_slots=args.slots, s_max=96)
    rng = np.random.default_rng(0)
    pending = [Request(i, rng.integers(0, cfg.vocab_size, size=8),
                       max_new=args.max_new)
               for i in range(args.requests)]
    done = []
    t0 = time.monotonic()
    while pending or any(s is not None for s in server.slots):
        while pending and server.add_request(pending[0]):
            print(f"[serve] admitted request {pending[0].req_id}")
            pending.pop(0)
        if not server.decode_round():
            break
        for i, s in enumerate(server.slots):
            if s is not None and s.done:
                done.append(s)
                server.slots[i] = None
                print(f"[serve] finished request {s.req_id}: "
                      f"{s.generated[:6]}...")
    dt = time.monotonic() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {toks} new tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, {server.steps} decode steps)")


if __name__ == "__main__":
    main()
