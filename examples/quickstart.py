"""Quickstart: train a small LM end-to-end with the full framework stack.

    PYTHONPATH=src python examples/quickstart.py

Builds the reduced qwen3-8b config (~0.3M params on CPU; pass --arch/--steps
to change), trains a few hundred steps with AdamW + warmup-cosine under the
ResilientTrainer (atomic checkpoints every 50 steps), and prints the loss
curve.  This is the (b)-deliverable end-to-end driver in its smallest form;
``python -m repro.launch.train`` exposes the same path with all knobs.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import registry
from repro.configs.base import ParallelismConfig
from repro.distributed.ft import FTConfig, ResilientTrainer
from repro.launch.train import lm_batch_source
from repro.models.model import build
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.step import build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch)
    model = build(cfg)
    print(f"[quickstart] {cfg.name} (reduced): {model.n_params():,} params")

    params = model.init(jax.random.key(0))
    opt = AdamW(lr=1e-3, schedule=warmup_cosine(1e-3, 20, args.steps))
    trainer = ResilientTrainer(
        step_fn=jax.jit(build_train_step(model, ParallelismConfig(), opt)),
        params=params, opt_state=opt.init(params),
        cfg=FTConfig(ckpt_dir="/tmp/quickstart_ckpt", ckpt_every=50),
        batch_source=lm_batch_source(model, args.batch, args.seq))

    t0 = time.monotonic()
    hist = trainer.run(args.steps)
    dt = time.monotonic() - t0
    print(f"[quickstart] {len(hist)} steps in {dt:.1f}s "
          f"({len(hist) * args.batch * args.seq / dt:,.0f} tok/s)")
    for i in range(0, len(hist), max(len(hist) // 10, 1)):
        print(f"  step {hist[i]['step']:4d}  loss {hist[i]['loss']:.3f}")
    print(f"  step {hist[-1]['step']:4d}  loss {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"]
    print("[quickstart] OK — loss decreased; checkpoints in "
          "/tmp/quickstart_ckpt")


if __name__ == "__main__":
    main()
