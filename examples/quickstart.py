"""Quickstart: the `repro.api` facade end-to-end on CPU.

    PYTHONPATH=src python examples/quickstart.py

Opens a :class:`repro.api.SenecaServer` over a synthetic image dataset,
pulls a session, feeds a threaded DSI pipeline (storage -> MDP-partitioned
cache -> ODS -> augment) into a reduced ViT training loop, and prints the
server's stats — the smallest real run of the paper's whole stack.  Pass
``--backend jax`` to route batch substitution through the fused
``ods_jax.substitute_jit`` kernel behind the same API.

``--lm`` instead runs the original LM driver (reduced qwen3-8b under the
ResilientTrainer with atomic checkpoints); ``python -m repro.launch.train``
exposes the same paths with all knobs.

``--open-loop RATE`` replaces the closed training loop with trace-timed
request arrivals at RATE req/s (VirtualClock-deterministic, SLO
admission control) and prints exact p50/p99/p999 latency with a
per-phase breakdown — docs/API.md "Open-loop serving & SLOs".
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.api import FaultSpec, JobSpec, SenecaServer, WorkloadRunner
from repro.configs import registry
from repro.configs.base import ParallelismConfig
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.models.model import build
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.step import build_train_step


def _make_dataset(args):
    """The synthetic dataset, optionally materialized as sharded files
    (--dataset-dir: real file IO through the same token bucket)."""
    ds = tiny(n=1024)
    if args.dataset_dir:
        from repro.data.synthetic import FileDataset
        ds = FileDataset(ds, args.dataset_dir)
        print(f"[quickstart] dataset: {ds.name} "
              f"({ds.n_shards} shard file(s) in {args.dataset_dir})")
    return ds


def _spill_kwargs(args, ds) -> dict:
    """--cache-spill-dir: turn every cache partition into a DRAM→disk
    tier chain (docs/API.md \"Storage engine & cache tiers\")."""
    if not args.cache_spill_dir:
        return {}
    spill = int(0.5 * ds.n_samples * ds.augmented_bytes())
    return {"spill_dir": args.cache_spill_dir, "spill_bytes": spill}


def _device_kwargs(args) -> dict:
    """--device-cache-bytes: add a device-resident HBM cache tier in
    front of DRAM (docs/API.md "Device-resident preprocessing & the
    HBM tier"); pair with ``--executor device`` for the fused
    decode+augment route."""
    if not args.device_cache_bytes:
        return {}
    return {"device_cache_bytes": args.device_cache_bytes}


def _print_tier_labels(server, args) -> None:
    svc = server.service
    parts = [server.partition.label]
    if svc.hbm_partition is not None:
        parts.insert(0, svc.hbm_partition.label)
    if svc.disk_partition is not None:
        parts.append(svc.disk_partition.label)
    if len(parts) > 1:
        levels = ["hbm"] if svc.hbm_partition is not None else []
        levels.append("dram")
        if svc.disk_partition is not None:
            levels.append("disk")
        print(f"[quickstart] {'|'.join(levels)} partition: "
              f"{'|'.join(parts)}")
    if svc.hbm_partition is not None:
        print(f"[quickstart] device cache tier: "
              f"{args.device_cache_bytes} bytes, hbm split "
              f"{svc.hbm_partition.label}")


def _shard_kwargs(args) -> dict:
    """--shards N: route the cache through the sharded data plane
    (docs/API.md \"Sharded data plane\")."""
    if args.shards <= 1 and args.shard_transport == "sim":
        return {}
    return {"shards": args.shards, "shard_transport": args.shard_transport}


def _print_shard_stats(stats) -> None:
    for s in stats.get("shards", ()):
        print(f"[quickstart]   shard {s['shard']}: "
              f"hit_rate={s['hit_rate']:.3f} entries={s['entries']} "
              f"bytes={s['bytes_used']}")


def run_seneca(args) -> None:
    # -- the docs/API.md quickstart, verbatim ---------------------------
    ds = _make_dataset(args)
    server = SenecaServer.for_dataset(ds, cache_frac=0.35, seed=0,
                                      backend=args.backend,
                                      augment_backend=args.augment_backend,
                                      repartition=args.repartition,
                                      **_spill_kwargs(args, ds),
                                      **_device_kwargs(args),
                                      **_shard_kwargs(args))
    print(f"[quickstart] MDP partition: {server.partition.label} "
          f"(backend={args.backend}, executor={args.executor}, "
          f"augment={args.augment_backend}, "
          f"repartition={args.repartition}, shards={args.shards})")
    if server.service.disk_partition is not None:
        print(f"[quickstart] spill tier: disk split "
              f"{server.service.disk_partition.label} in "
              f"{args.cache_spill_dir}")
    _print_tier_labels(server, args)

    cfg = registry.get_reduced("vit-huge")
    model = build(cfg)
    print(f"[quickstart] {cfg.name} (reduced): {model.n_params():,} params")
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=1e-3, schedule=warmup_cosine(1e-3, 10, args.steps))
    state = opt.init(params)
    step = jax.jit(build_train_step(model, ParallelismConfig(), opt))

    losses = []
    t0 = time.monotonic()
    with server.open_session(batch_size=args.batch) as sess:
        pipe = DSIPipeline(sess, RemoteStorage(ds), n_workers=3,
                           executor=args.executor)
        for _ in range(args.steps):
            raw = pipe.next_batch()
            B = raw["images"].shape[0]
            flat = raw["images"].reshape(B, -1)
            T, D = cfg.frontend_tokens, cfg.d_model
            reps = -(-T * D // flat.shape[1])
            emb = np.tile(flat, (1, reps))[:, :T * D].reshape(B, T, D)
            batch = {"patch_embeds": jax.numpy.asarray(emb,
                                                       jax.numpy.bfloat16),
                     "labels": jax.numpy.asarray(
                         raw["labels"] % cfg.n_classes)}
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
        stats = sess.stats()
        pipe.stop()
    dt = time.monotonic() - t0

    print(f"[quickstart] {len(losses)} steps in {dt:.1f}s "
          f"({len(losses) * args.batch / dt:.1f} samples/s)")
    print(f"[quickstart] loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"[quickstart] ods_hit_rate={stats['ods_hit_rate']:.3f} "
          f"substitutions={stats['substitutions']} "
          f"tier_counts={stats['tier_counts']}")
    if "residency_counts" in stats:
        extra = []
        if "disk_bytes_used" in stats:
            extra.append(f"disk_bytes_used={stats['disk_bytes_used']}")
        if "hbm_bytes_used" in stats:
            extra.append(f"hbm_bytes_used={stats['hbm_bytes_used']}")
        print(f"[quickstart] residency={stats['residency_counts']} "
              + " ".join(extra))
    _print_shard_stats(stats)
    rp = stats["repartitions"]
    if rp["applied"]:
        last = rp["last_applied"]
        print(f"[quickstart] repartitioned {rp['applied']}x "
              f"({last['from']} -> {last['to']}, "
              f"predicted gain {last['predicted_gain']:+.1%}); "
              f"live partition: {rp['partition']}")
    else:
        print(f"[quickstart] live partition: {rp['partition']} "
              f"(mode={rp['mode']}, no repartition applied)")
    server.close()      # drops spill-tier files when --cache-spill-dir
    assert np.isfinite(losses).all()
    assert stats["hits"] + stats["misses"] > 0
    print("[quickstart] OK — trained through the repro.api facade")


def _fault_trace(args) -> list:
    """--inject-faults: a small mixed-domain fault trace scaled to the
    run's configuration (docs/API.md "Fault tolerance & elasticity") —
    the preempted job is restored from its sampler checkpoint, so the
    epoch accounting below still holds exactly."""
    faults = [
        FaultSpec("worker-crash", at_s=0.5, job="job0"),
        FaultSpec("preempt", at_s=1.0, job="job0", duration_s=0.5),
        FaultSpec("bandwidth-collapse", at_s=0.8, factor=0.5,
                  duration_s=0.6),
    ]
    if args.shards > 1:
        faults.append(FaultSpec("shard-kill", at_s=0.7,
                                shard=args.shards - 1, duration_s=0.5))
    if args.cache_spill_dir:
        faults.append(FaultSpec("spill-corrupt", at_s=0.9, n_files=2))
    return faults


def run_multi(args) -> None:
    """``--jobs N``: N concurrent sessions sharing one Seneca cache,
    driven by the multi-job WorkloadRunner (docs/API.md "Multi-job
    workloads") — each job is a DSIPipeline with a rate-limited consumer
    emulating its GPU's ingest rate."""
    ds = _make_dataset(args)
    server = SenecaServer.for_dataset(ds, cache_frac=0.35, seed=0,
                                      backend=args.backend,
                                      augment_backend=args.augment_backend,
                                      repartition=args.repartition,
                                      **_spill_kwargs(args, ds),
                                      **_device_kwargs(args),
                                      **_shard_kwargs(args))
    print(f"[quickstart] MDP partition: {server.partition.label} "
          f"({args.jobs} concurrent jobs, one shared cache, "
          f"{args.shards} shard(s))")
    _print_tier_labels(server, args)
    rates = [900, 500, 700, 1100, 600, 800][:args.jobs] or [900]
    trace = [JobSpec(f"job{i}", arrival_s=0.4 * i, epochs=1,
                     batch_size=args.batch, gpu_rate=rates[i % len(rates)],
                     executor=args.executor, n_workers=2)
             for i in range(args.jobs)]
    storage = RemoteStorage(ds, bandwidth=60e6)
    faults = _fault_trace(args) if args.inject_faults else []
    if faults:
        print(f"[quickstart] injecting {len(faults)} fault(s): "
              + ", ".join(f.kind for f in faults))
    runner = WorkloadRunner(server, storage, record_ids=False,
                            faults=faults, fault_policy="checkpoint")
    res = runner.run(trace, timeout=600)
    for job in res.jobs:
        extra = ""
        if job.preemptions or job.worker_restarts:
            extra = (f", {job.preemptions} preemption(s), "
                     f"{job.worker_restarts} worker restart(s)")
        print(f"[quickstart]   {job.spec.name}: arrived "
              f"{job.spec.arrival_s:.1f}s, {job.samples} samples in "
              f"{job.duration_s:.1f}s ({job.epochs_completed} epoch(s)"
              f"{extra})")
    stats = res.stats
    print(f"[quickstart] makespan {res.makespan:.1f}s  "
          f"ods_hit_rate={stats['ods_hit_rate']:.3f} "
          f"substitutions={stats['substitutions']}")
    _print_shard_stats(stats)
    fstats = (stats or {}).get("faults")
    if fstats:
        print(f"[quickstart] faults injected={fstats['injected']} "
              f"recovered={fstats['recovered']} "
              f"shard_failovers={fstats['shard_failovers']}")
    server.close()
    # each job consumes one whole-batch epoch pass (the runner's epoch
    # accounting — exact even when --batch does not divide the dataset;
    # with --inject-faults the checkpoint/restore policy keeps it exact
    # through the preemption too)
    epoch_size = (ds.n_samples // args.batch) * args.batch
    assert res.ok and res.total_samples == args.jobs * epoch_size
    assert all(j.epochs_completed == 1 for j in res.jobs)
    if args.inject_faults:
        assert sum(j.preemptions for j in res.jobs) == 1
    print(f"[quickstart] OK — {args.jobs} jobs shared one Seneca cache")


def run_open_loop(args) -> None:
    """``--open-loop RATE``: drive the server with trace-timed request
    arrivals instead of a closed training loop (docs/API.md "Open-loop
    serving & SLOs") — a VirtualClock replays the schedule
    deterministically, the SLO admission controller degrades/sheds under
    overload, and the exact latency percentiles are printed per phase."""
    from repro.api import SLO
    from repro.workload import (OpenLoopGenerator, VirtualClock,
                                poisson_arrivals)

    ds = _make_dataset(args)
    server = SenecaServer.for_dataset(ds, cache_frac=0.35, seed=0,
                                      backend=args.backend,
                                      **_spill_kwargs(args, ds))
    clock = VirtualClock()
    storage = RemoteStorage(ds, bandwidth=8e6, clock=clock)
    slo = SLO(p99_target_s=args.slo_p99, max_queue=64)
    gen = OpenLoopGenerator(server, storage, clock=clock, slo=slo,
                            n_workers=2, seed=0,
                            phase_costs={"decode": 0.004,
                                         "augment": 0.003})
    n = args.steps * args.batch
    res = gen.run(poisson_arrivals(args.open_loop, n=n, seed=0))
    print(f"[quickstart] open-loop @ {args.open_loop:.0f} req/s, "
          f"{n} requests, SLO p99 target {args.slo_p99 * 1e3:.0f}ms: "
          f"{res.counts}")
    lat = res.percentiles()
    if lat:
        print(f"[quickstart] latency p50={lat['p50'] * 1e3:.2f}ms "
              f"p99={lat['p99'] * 1e3:.2f}ms "
              f"p999={lat['p999'] * 1e3:.2f}ms "
              f"(virtual makespan {res.makespan_s:.2f}s)")
        for phase, pcts in sorted(res.phase_percentiles().items()):
            print(f"[quickstart]   {phase:>8}: "
                  f"p50={pcts['p50'] * 1e3:.2f}ms "
                  f"p99={pcts['p99'] * 1e3:.2f}ms")
    stats = server.stats()
    req = stats["telemetry"]["requests"]
    print(f"[quickstart] stats()['telemetry']['requests']: "
          f"outcomes={req['outcomes']} "
          f"completed={req['completed']}")
    server.close()
    assert res.counts["served"] > 0
    print("[quickstart] OK — open-loop serving through the repro.api "
          "facade")


def run_lm(args) -> None:
    from repro.distributed.ft import FTConfig, ResilientTrainer
    from repro.launch.train import lm_batch_source

    cfg = registry.get_reduced(args.arch)
    model = build(cfg)
    print(f"[quickstart] {cfg.name} (reduced): {model.n_params():,} params")

    params = model.init(jax.random.key(0))
    opt = AdamW(lr=1e-3, schedule=warmup_cosine(1e-3, 20, args.steps))
    trainer = ResilientTrainer(
        step_fn=jax.jit(build_train_step(model, ParallelismConfig(), opt)),
        params=params, opt_state=opt.init(params),
        cfg=FTConfig(ckpt_dir="/tmp/quickstart_ckpt", ckpt_every=50),
        batch_source=lm_batch_source(model, args.batch, args.seq))

    t0 = time.monotonic()
    hist = trainer.run(args.steps)
    dt = time.monotonic() - t0
    print(f"[quickstart] {len(hist)} steps in {dt:.1f}s "
          f"({len(hist) * args.batch * args.seq / dt:,.0f} tok/s)")
    print(f"  loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"]
    print("[quickstart] OK — loss decreased; checkpoints in "
          "/tmp/quickstart_ckpt")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lm", action="store_true",
                    help="run the LM ResilientTrainer driver instead")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "jax"))
    ap.add_argument("--executor", default="per-sample",
                    choices=("per-sample", "stage-parallel", "device"),
                    help="DSI pipeline executor (stage-parallel = async "
                         "queue-fed stages; device = fused Pallas "
                         "decode+augment with device collate, "
                         "docs/API.md)")
    ap.add_argument("--augment-backend", default="numpy",
                    choices=("numpy", "pallas"),
                    help="batched augment engine for the stage-parallel "
                         "executor (pallas = fused kernel)")
    ap.add_argument("--repartition", default="static",
                    choices=("static", "on-change", "adaptive"),
                    help="live cache repartitioning mode (docs/API.md)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="run N concurrent sessions over one shared "
                         "cache via the WorkloadRunner (docs/API.md "
                         "\"Multi-job workloads\") instead of the "
                         "single-job training loop")
    ap.add_argument("--inject-faults", action="store_true",
                    help="with --jobs N: inject a worker crash, a job "
                         "preemption, a storage-bandwidth collapse — "
                         "plus a shard kill with --shards > 1 and a "
                         "spill corruption with --cache-spill-dir — and "
                         "recover through the checkpoint/restore policy "
                         "(docs/API.md \"Fault tolerance & "
                         "elasticity\")")
    ap.add_argument("--shards", type=int, default=1,
                    help="split the cache across N consistent-hash "
                         "shards (docs/API.md \"Sharded data plane\"); "
                         "prints per-shard hit rates at the end")
    ap.add_argument("--shard-transport", default="sim",
                    choices=("sim", "process"),
                    help="sharded data-plane transport: in-process "
                         "deterministic shards, or one OS process per "
                         "shard")
    ap.add_argument("--device-cache-bytes", type=int, default=0,
                    help="device-resident HBM cache tier budget in "
                         "bytes (0 = off): augmented rows are served "
                         "zero-copy on device and the form×tier MDP "
                         "solves a third simplex (docs/API.md "
                         "\"Device-resident preprocessing & the HBM "
                         "tier\")")
    ap.add_argument("--cache-spill-dir", default=None,
                    help="SSD spill directory: every cache partition "
                         "becomes a DRAM→disk tier chain sized by the "
                         "form×tier MDP (docs/API.md \"Storage engine "
                         "& cache tiers\")")
    ap.add_argument("--open-loop", type=float, default=None,
                    metavar="RATE",
                    help="drive the server open-loop at RATE req/s "
                         "(Poisson arrivals on a VirtualClock, SLO "
                         "admission control) and print latency "
                         "percentiles instead of training (docs/API.md "
                         "\"Open-loop serving & SLOs\")")
    ap.add_argument("--slo-p99", type=float, default=0.05,
                    help="open-loop p99 latency target in seconds")
    ap.add_argument("--dataset-dir", default=None,
                    help="materialize the synthetic dataset as "
                         "write-once sharded files here and serve "
                         "fetches from them (real file IO through the "
                         "storage token bucket)")
    ap.add_argument("--steps", type=int, default=None,
                    help="training steps (default: 30, or 200 with --lm)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    if args.inject_faults and (args.lm or args.jobs < 2):
        ap.error("--inject-faults needs the multi-job runner: "
                 "pass --jobs N (N >= 2) without --lm")
    if args.steps is None:
        args.steps = 200 if args.lm else 30
    if args.open_loop is not None and (args.lm or args.jobs > 1):
        ap.error("--open-loop replaces the training loop: drop --lm / "
                 "--jobs")
    if args.lm:
        run_lm(args)
    elif args.open_loop is not None:
        run_open_loop(args)
    elif args.jobs > 1:
        run_multi(args)
    else:
        run_seneca(args)


if __name__ == "__main__":
    main()
