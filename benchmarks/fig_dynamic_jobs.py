"""Dynamic job mix: staggered session arrivals/departures, ``static`` vs
``adaptive`` repartitioning on the *live* threaded stack.

The paper's concurrent-jobs experiments (Fig. 14, §7) hold the job set
fixed, so the construction-time MDP split stays valid; this benchmark is
the scenario none of the fig* harnesses could run before — jobs arrive
and leave mid-run while observed stage costs (CPU decode/augment on this
host, token-bucket storage) diverge from the Table-3 profile.  The
``adaptive`` server calibrates its performance model from pipeline
telemetry and resizes the TieredCache in place; ``static`` keeps the
construction split.

Three phases over one shared server/storage per mode:

  A. one session warms the cache alone;
  B. two more sessions arrive (3 concurrent pipelines);
  C. the two newcomers leave, the original session finishes.

Emits ``BENCH_dynamic.json`` (benchmarks/common.write_bench_json) with
per-mode aggregate hit rates and the repartition event log, plus the
usual ``name,us,derived`` rows for run.py.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from benchmarks.common import write_bench_json
from repro.api import SenecaServer
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny


def _drain(pipes: List[DSIPipeline], n_batches: int) -> None:
    """Round-robin ``n_batches`` from each pipeline (interleaved, so the
    sessions genuinely contend for the shared cache + storage budget)."""
    for _ in range(n_batches):
        for pipe in pipes:
            pipe.next_batch()


def run_mode(mode: str, *, n_samples: int, batch: int,
             phase_batches: Tuple[int, int, int],
             bandwidth: float, seed: int = 0) -> Dict:
    ds = tiny(n=n_samples)
    server = SenecaServer.for_dataset(
        ds, cache_frac=0.3, seed=seed, repartition=mode,
        repartition_cooldown=0.0, telemetry_min_samples=16)
    storage = RemoteStorage(ds, bandwidth=bandwidth)
    initial = server.partition.label

    def open_pipe() -> DSIPipeline:
        sess = server.open_session(batch_size=batch)
        return DSIPipeline(sess, storage, n_workers=3, seed=seed)

    a, b, c = phase_batches
    p0 = open_pipe()
    _drain([p0], a)                       # phase A: lone job
    p1, p2 = open_pipe(), open_pipe()
    _drain([p0, p1, p2], b)               # phase B: arrivals -> 3 jobs
    p1.stop()
    p2.stop()
    _drain([p0], c)                       # phase C: departures
    stats = server.stats()
    p0.stop()
    server.close()

    rp = stats["repartitions"]
    return {
        "mode": mode,
        "partition_initial": initial,
        "partition_final": rp["partition"],
        "cache_hit_rate": stats["cache_lookup_hit_rate"],
        "ods_hit_rate": stats["ods_hit_rate"],
        "substitutions": stats["substitutions"],
        "storage_fetches": storage.fetches,
        "repartitions": {k: rp[k] for k in
                         ("mode", "resolves", "applied", "skipped")},
        "last_applied": rp["last_applied"],
        "tier_counts": stats["tier_counts"],
    }


def run(full: bool = False) -> List[Tuple[str, str]]:
    knobs = dict(n_samples=3_072 if full else 384, batch=16,
                 phase_batches=(16, 16, 12) if full else (8, 8, 6),
                 bandwidth=30e6)
    results = {mode: run_mode(mode, **knobs)
               for mode in ("static", "adaptive")}
    payload = {"config": {k: str(v) for k, v in knobs.items()},
               **results}
    path = write_bench_json("dynamic", payload)

    rows = []
    for mode, r in results.items():
        rows.append((
            f"fig_dynamic/{mode}",
            f"hit={r['cache_hit_rate']:.3f} ods={r['ods_hit_rate']:.3f} "
            f"applied={r['repartitions']['applied']} "
            f"split={r['partition_initial']}->{r['partition_final']}"))
    adaptive, static = results["adaptive"], results["static"]
    rows.append((
        "fig_dynamic/summary",
        f"adaptive-static hit delta="
        f"{adaptive['cache_hit_rate'] - static['cache_hit_rate']:+.3f} "
        f"events={adaptive['repartitions']['applied']} json={path}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for name, derived in run(full=args.full):
        print(f"{name},{derived}")
