"""Fig. 10: 12-job trace makespan — Seneca vs the PyTorch-like baseline.

The paper schedules 12 image-classification jobs (mixed model sizes,
random arrivals, <=2 concurrent) on ImageNet-1K for 50 epochs each and
reports Seneca reducing total training time by 45.23% vs PyTorch.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, scaled, scaled_cache
from repro.api import (AWS_P3, DSISimulator, GB, IMAGENET_1K, PYTORCH,
                       SENECA, SimJob)

# per-model GPU ingest rates (samples/s on V100s, DS-Analyzer-style mix:
# small models fast, ViT/VGG slow) for the 12-job trace
JOB_MIX = [9000, 4200, 2600, 9000, 5200, 1800, 9000, 4200, 2600, 5200,
           1800, 1400]


def run(full: bool = False):
    epochs = 4 if full else 2
    ds = scaled(IMAGENET_1K)
    cache = scaled_cache(400 * GB)
    rng = np.random.default_rng(0)
    arrivals = np.sort(rng.uniform(0, 200, len(JOB_MIX)))
    out = {}
    for spec in (PYTORCH, SENECA):
        sim = DSISimulator(AWS_P3, ds, spec, cache_bytes=cache, seed=2)
        jobs = [SimJob(j, gpu_rate=JOB_MIX[j], batch_size=512,
                       epochs=epochs, arrival_s=float(arrivals[j]))
                for j in range(len(JOB_MIX))]
        out[spec.name] = sim.run(jobs)
    red = 1 - out["seneca"].makespan / out["pytorch"].makespan
    return [
        ("fig10/pytorch_makespan_s", f"{out['pytorch'].makespan:.0f}"),
        ("fig10/seneca_makespan_s", f"{out['seneca'].makespan:.0f}"),
        ("fig10/reduction",
         f"{red * 100:.1f}% (paper: 45.23%)"),
    ]


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
