"""Table 6: MDP cache splits per (dataset x hardware).

Reports our brute-force optimum, the paper's published split, and the
throughput gap between them under the same equations — plus the size of the
iso-optimal region (splits within 1% of the optimum), which shows most
disagreements sit inside a flat tie-zone (EXPERIMENTS.md §MDP).
"""
from __future__ import annotations

import numpy as np

from repro.core import mdp
from repro.core.perf_model import (DATASETS, EVAL_PROFILES, JobProfile,
                                   dsi_throughput)

PAPER = {
    ("imagenet-1k", "in-house"): "58-42-0",
    ("imagenet-1k", "2x-in-house"): "40-59-1",
    ("imagenet-1k", "aws-p3.8xlarge"): "0-81-19",
    ("imagenet-1k", "azure-nc96ads"): "0-48-52",
    ("imagenet-1k", "2x-azure"): "0-53-47",
    ("openimages-v7", "in-house"): "62-37-1",
    ("openimages-v7", "2x-in-house"): "58-41-1",
    ("openimages-v7", "aws-p3.8xlarge"): "52-48-0",
    ("openimages-v7", "azure-nc96ads"): "5-95-0",
    ("openimages-v7", "2x-azure"): "6-93-1",
    ("imagenet-22k", "in-house"): "100-0-0",
    ("imagenet-22k", "2x-in-house"): "100-0-0",
    ("imagenet-22k", "aws-p3.8xlarge"): "100-0-0",
    ("imagenet-22k", "azure-nc96ads"): "100-0-0",
    ("imagenet-22k", "2x-azure"): "100-0-0",
}


def run(full: bool = False):
    rows = []
    agree_1pct = 0
    for ds in DATASETS:
        for hw in EVAL_PROFILES:
            ours = mdp.optimize(hw, ds)
            lab = PAPER[(ds.name, hw.name)]
            pe, pd, pa = [int(v) / 100 for v in lab.split("-")]
            theirs = float(dsi_throughput(hw, ds, JobProfile(),
                                          pe, pd, pa).overall)
            gap = (ours.throughput - theirs) / ours.throughput
            if gap <= 0.01:
                agree_1pct += 1
            xe, xd, xa, tp = mdp.sweep(hw, ds, step=0.05)
            iso = float(np.mean(tp >= ours.throughput * 0.99))
            rows.append((
                f"table6/{ds.name}/{hw.name}",
                f"ours={ours.label} paper={lab} gap={gap * 100:.1f}% "
                f"iso_region={iso * 100:.0f}%"))
    rows.append(("table6/summary",
                 f"{agree_1pct}/15 paper splits within 1% of our optimum"))
    return rows


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
