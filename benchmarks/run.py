"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses paper-scale
populations (slower); default is the 1/10 weak-scaled configuration whose
ratios match (benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma list of module tags (fig3,fig4,...)")
    args, _ = ap.parse_known_args()

    from benchmarks import (fig3_cache_forms, fig4_pagecache,
                            fig8_validation, fig10_makespan, fig13_hitrate,
                            fig14_concurrency, fig15_ect, fig_concurrency,
                            fig_device_pipeline, fig_dynamic_jobs,
                            fig_fault_recovery, fig_live_makespan,
                            fig_open_loop, fig_pipeline_throughput,
                            fig_sharded, fig_tiered_cache, roofline_report,
                            table6_mdp)
    modules = [
        ("fig3", fig3_cache_forms), ("fig4", fig4_pagecache),
        ("table6", table6_mdp), ("fig8", fig8_validation),
        ("fig10", fig10_makespan), ("fig13", fig13_hitrate),
        ("fig14", fig14_concurrency), ("fig15", fig15_ect),
        ("dynamic", fig_dynamic_jobs),
        ("pipeline", fig_pipeline_throughput),
        ("device", fig_device_pipeline),
        ("live", fig_live_makespan),
        ("tiered", fig_tiered_cache),
        ("sharded", fig_sharded),
        ("faults", fig_fault_recovery),
        ("concurrency", fig_concurrency),
        ("openloop", fig_open_loop),
        ("roofline", roofline_report),
    ]
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for tag, mod in modules:
        if only and tag not in only:
            continue
        t0 = time.monotonic()
        try:
            rows = mod.run(full=args.full)
        except Exception as e:          # keep the harness running
            print(f"{tag}/ERROR,0,{e!r}")
            continue
        us = (time.monotonic() - t0) * 1e6 / max(len(rows), 1)
        for name, derived in rows:
            print(f'{name},{us:.0f},"{derived}"')
        sys.stdout.flush()


if __name__ == "__main__":
    main()
