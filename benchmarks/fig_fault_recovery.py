"""Fault recovery makespan: checkpoint/restore vs naive restart vs fault-free.

The fault-tolerance layer's claim (ISSUE-8, motivated by CoorDL's
data-stalls analysis): a preempted job re-admitted with its sampler
state restored — seen-mask, epoch counters, permutation + RNG position
(``Session.checkpoint_state()``) — resumes without re-fetching or
re-preprocessing anything it already consumed, so the workload makespan
degrades by roughly the preemption dwell, not by a from-scratch rerun.

Three modes over one deterministic ``VirtualClock`` trace (3 staggered
jobs on a shared sharded server, shard-kill + spill-corruption + a
mid-run preemption):

* **fault-free** — the same trace with no faults (lower bound);
* **recovery** — faults injected, ``fault_policy="checkpoint"``:
  preempted jobs snapshot + restore sampler state on re-admission;
* **naive-restart** — same faults, ``fault_policy="restart"``: the
  preempted job loses all progress and replays from sample 0 (the
  kill-and-restart-from-scratch baseline).

Every mode must finish with exact once-per-epoch coverage per job, and
the virtual clock makes each mode's makespan a deterministic number —
the benchmark reruns the recovery mode and asserts byte-equality.

Emits ``BENCH_faults.json``; ``--check`` asserts (1) recovery makespan
strictly beats naive restart, (2) recovery overhead over fault-free is
bounded, (3) per-job epoch coverage is exact, (4) determinism holds.
"""
from __future__ import annotations

import tempfile
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import write_bench_json
from repro.api import (FaultSpec, JobSpec, SenecaServer, VirtualClock,
                       WorkloadRunner)
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny

N_SAMPLES = 256
BATCH = 16
JOBS = ((("a", 0.00, 1000)), (("b", 0.05, 600)), (("c", 0.10, 1500)))
PREEMPT_DWELL_S = 0.12


def _trace(epochs: int) -> List[JobSpec]:
    return [JobSpec(name, arrival_s=at, epochs=epochs, batch_size=BATCH,
                    gpu_rate=rate) for name, at, rate in JOBS]


def _faults() -> List[FaultSpec]:
    return [
        FaultSpec("shard-kill", at_s=0.10, shard=1, duration_s=0.15),
        FaultSpec("spill-corrupt", at_s=0.15, n_files=2),
        # preempt the slowest job (the one that sets the makespan) once
        # it has real progress to lose — the naive-restart penalty is
        # the replay of everything consumed before t=0.45
        FaultSpec("preempt", at_s=0.45, job="b",
                  duration_s=PREEMPT_DWELL_S),
    ]


def _coverage_exact(sample_ids: List[int], n: int) -> bool:
    """Every consecutive n-sample window is a permutation of range(n)
    (BATCH divides N_SAMPLES, so epochs tile exactly)."""
    ids = np.asarray(sample_ids)
    epochs = len(ids) // n
    if epochs * n != len(ids):
        return False
    want = np.arange(n)
    return all(
        np.array_equal(np.sort(ids[e * n:(e + 1) * n]), want)
        for e in range(epochs))


def run_mode(mode: str, *, epochs: int, seed: int = 0) -> Dict:
    ds = tiny(n=N_SAMPLES)
    spill = tempfile.mkdtemp(prefix="bench-faults-")
    server = SenecaServer.for_dataset(
        ds, cache_frac=0.3, seed=seed, shards=2, spill_dir=spill,
        spill_bytes=int(0.2 * N_SAMPLES * ds.augmented_bytes()))
    storage = RemoteStorage(ds)
    faults = [] if mode == "fault-free" else _faults()
    policy = "restart" if mode == "naive-restart" else "checkpoint"
    runner = WorkloadRunner(server, storage, clock=VirtualClock(),
                            seed=seed, faults=faults,
                            fault_policy=policy)
    res = runner.run(_trace(epochs), timeout=600)
    stats = res.stats
    server.close()
    return {
        "mode": mode,
        "makespan_s": res.makespan,
        "wall_s": res.wall_s,
        "total_samples": res.total_samples,
        "storage_fetches": storage.fetches,
        "per_job_s": {j.spec.name: round(j.duration_s, 4)
                      for j in res.jobs},
        "preemptions": sum(j.preemptions for j in res.jobs),
        "coverage_exact": all(
            _coverage_exact(j.sample_ids, N_SAMPLES) for j in res.jobs),
        "sample_id_digest": [hash(tuple(j.sample_ids))
                             for j in res.jobs],
        "faults": (stats or {}).get("faults"),
    }


def run(full: bool = False) -> List[Tuple[str, str]]:
    epochs = 3 if full else 2
    results = {mode: run_mode(mode, epochs=epochs)
               for mode in ("fault-free", "recovery", "naive-restart")}
    rerun = run_mode("recovery", epochs=epochs)
    deterministic = (
        rerun["makespan_s"] == results["recovery"]["makespan_s"]
        and rerun["sample_id_digest"]
        == results["recovery"]["sample_id_digest"])
    free = results["fault-free"]["makespan_s"]
    rec = results["recovery"]["makespan_s"]
    naive = results["naive-restart"]["makespan_s"]
    payload = {
        "config": {"n_samples": N_SAMPLES, "batch": BATCH,
                   "epochs": epochs,
                   "preempt_dwell_s": PREEMPT_DWELL_S},
        "recovery_vs_naive": 1 - rec / naive,
        "recovery_overhead_vs_fault_free": rec / free - 1,
        "deterministic": deterministic,
        **results,
    }
    path = write_bench_json("faults", payload)
    rows = [(f"fig_fault_recovery/{m}",
             f"makespan={r['makespan_s']:.3f}s "
             f"fetches={r['storage_fetches']} "
             f"coverage={'exact' if r['coverage_exact'] else 'BROKEN'}")
            for m, r in results.items()]
    rows.append((
        "fig_fault_recovery/summary",
        f"recovery beats naive restart by "
        f"{payload['recovery_vs_naive'] * 100:.1f}%, overhead vs "
        f"fault-free {payload['recovery_overhead_vs_fault_free'] * 100:.1f}%"
        f" deterministic={deterministic} json={path}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="assert recovery < naive restart, bounded "
                         "overhead vs fault-free, exact coverage, "
                         "deterministic reruns")
    args = ap.parse_args()
    out_rows = run(full=args.full)
    for name, derived in out_rows:
        print(f"{name},{derived}")
    if args.check:
        import json
        with open("BENCH_faults.json") as f:
            bench = json.load(f)
        for mode in ("fault-free", "recovery", "naive-restart"):
            assert bench[mode]["coverage_exact"], (
                f"{mode}: per-epoch sample coverage is not exact")
        assert bench["deterministic"], (
            "two identical recovery runs were not byte-for-byte equal")
        rec = float(bench["recovery"]["makespan_s"])
        naive = float(bench["naive-restart"]["makespan_s"])
        free = float(bench["fault-free"]["makespan_s"])
        assert rec < naive, (
            f"recovery makespan {rec:.3f}s did not beat naive restart "
            f"{naive:.3f}s")
        assert rec / free - 1 < 1.0, (
            f"recovery overhead vs fault-free too large: "
            f"{rec / free - 1:.2f}")
        print(f"CHECK OK: recovery {rec:.3f}s < naive {naive:.3f}s, "
              f"overhead vs fault-free {rec / free - 1:.1%}")
