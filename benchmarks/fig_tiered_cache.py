"""Tiered storage engine: DRAM-only vs DRAM+SSD-spill under a
constrained DRAM budget.

The paper models the cache as one DRAM pool; production DSI systems
(CoorDL's MinIO SSD cache, tf.data's spill-to-disk) add a second tier.
This benchmark runs the *live* stack — sharded on-disk dataset
(:class:`~repro.data.synthetic.FileDataset`, real file IO through the
token-bucket storage budget), threaded DSI pipeline, ODS sampling —
twice over identical inputs:

* ``dram-only``   — the classic engine with a DRAM budget far below the
  working set, so most serves fall through to throttled remote storage;
* ``dram+spill``  — same DRAM budget plus an SSD spill directory: DRAM
  evictions/overflow demote to per-entry files (ndarrays re-read via
  ``np.memmap``), the form×tier MDP sizes both levels, and ODS prefers
  DRAM hits over disk hits over storage misses.

Measurement: one cold epoch of warmup (both modes pay the same storage
bill), then the median of three timed windows inside the warm regime —
where the spill tier turns would-be storage misses into local disk hits.

Both modes run the *same manual DRAM split* (encoded/decoded only —
with one job the refcount rule evicts every augmented sample after a
single serve, so an augmented tier would only add refill churn), so the
measured delta isolates the tier chain itself; the form×tier MDP's own
split choices are covered by tests/test_tiers.py and reported in the
JSON artifact.

Emits ``BENCH_tiered.json``; ``--check`` (the CI smoke gate) asserts
(1) spill throughput strictly above DRAM-only at the constrained
budget, (2) demoted entries re-served from disk are byte-identical to
the storage originals, and (3) ``server.close()`` leaves no spill files
behind.
"""
from __future__ import annotations

import os
import shutil
import statistics
import tempfile
import time
from typing import Dict, List, Tuple

from benchmarks.common import write_bench_json
from repro.api import SenecaServer
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import FileDataset, tiny


def _leftover_files(root: str) -> List[str]:
    return [os.path.join(dp, f)
            for dp, _dirs, fs in os.walk(root) for f in fs]


#: one DRAM split for both modes (controlled comparison) and the disk
#: split the spill mode layers under it: encoded+decoded only, sized so
#: the disk level covers the dataset's decoded working set
DRAM_SPLIT = (0.2, 0.8, 0.0)
SPILL_SPLIT = (0.35, 0.65, 0.0)


def run_mode(fd: FileDataset, spill_dir: str, *, dram_frac: float,
             spill_frac: float, batch: int, warmup_batches: int,
             windows: int, window_batches: int, bandwidth: float,
             n_workers: int, seed: int = 0) -> Tuple[Dict, List[str]]:
    aug_total = fd.n_samples * fd.augmented_bytes()
    spill_bytes = int(spill_frac * aug_total)
    server = SenecaServer.for_dataset(
        fd, cache_frac=dram_frac, seed=seed,
        split=DRAM_SPLIT,
        spill_dir=spill_dir if spill_bytes else None,
        spill_bytes=spill_bytes,
        spill_split=SPILL_SPLIT if spill_bytes else None)
    storage = RemoteStorage(fd, bandwidth=bandwidth)
    pipe = DSIPipeline(server.open_session(batch_size=batch), storage,
                       n_workers=n_workers, prefetch=2, seed=seed)
    for _ in range(warmup_batches):      # the cold first epoch
        pipe.next_batch()
    rates = []
    for _ in range(windows):
        t0 = time.monotonic()
        for _ in range(window_batches):
            pipe.next_batch()
        rates.append(window_batches * batch / (time.monotonic() - t0))
    stats = server.stats()

    # demote -> re-serve round-trip integrity: every encoded sample the
    # spill tier holds must read back byte-identical to its storage
    # original (decoded/augmented round-trips are pinned by the
    # property suite; encoded is the one directly comparable to the
    # dataset files here)
    roundtrip_checked = 0
    svc = server.service
    if svc.has_spill:
        with svc.cache.lock:
            part = svc.cache.parts["encoded"]
            disk_keys = part.spill.keys()[:16]
            values = [part.peek(k) for k in disk_keys]
        for k, value in zip(disk_keys, values):
            if value is None:
                continue
            assert bytes(value) == fd.encoded(k), \
                f"disk round-trip mismatch for sample {k}"
            roundtrip_checked += 1

    result = {
        "mode": "dram+spill" if spill_bytes else "dram-only",
        "samples_per_s": statistics.median(rates),
        "window_samples_per_s": [round(r, 1) for r in rates],
        "partition": stats["partition"],
        "disk_partition": stats.get("disk_partition"),
        "dram_bytes": int(dram_frac * aug_total),
        "spill_bytes": spill_bytes,
        "cache_hit_rate": stats["cache_lookup_hit_rate"],
        "ods_hit_rate": stats["ods_hit_rate"],
        "storage_fetches": storage.fetches,
        "residency_counts": stats.get("residency_counts"),
        "spill_traffic": stats.get("spill"),
        "b_disk_telemetry": stats["telemetry"].get("b_disk"),
        "disk_roundtrip_checked": roundtrip_checked,
    }
    pipe.stop()
    server.close()
    leftovers = _leftover_files(spill_dir) if spill_bytes else []
    return result, leftovers


def run(full: bool = False, check: bool = False) -> List[Tuple[str, str]]:
    work = tempfile.mkdtemp(prefix="seneca-tiered-")
    try:
        ds = tiny(n=2_048 if full else 1_024)
        fd = FileDataset(ds, os.path.join(work, "shards"))
        knobs = dict(dram_frac=0.06, batch=16,
                     warmup_batches=ds.n_samples // 16,
                     windows=3, window_batches=16 if full else 10,
                     bandwidth=6e6, n_workers=4)
        spill_dir = os.path.join(work, "spill")
        dram, leak_d = run_mode(fd, spill_dir, spill_frac=0.0, **knobs)
        spill, leak_s = run_mode(fd, spill_dir, spill_frac=0.9, **knobs)
        assert not leak_d and not leak_s, \
            f"server.close() leaked spill files: {leak_d or leak_s}"

        payload = {"config": {k: str(v) for k, v in knobs.items()},
                   "dataset": {"name": fd.name,
                               "n_samples": fd.n_samples,
                               "shards": fd.n_shards,
                               "total_bytes": fd.total_bytes()},
                   "dram-only": dram, "dram+spill": spill}
        path = write_bench_json("tiered", payload)

        base = dram["samples_per_s"]
        rows = []
        for r in (dram, spill):
            rows.append((
                f"fig_tiered/{r['mode']}",
                f"sps={r['samples_per_s']:.0f} "
                f"x{r['samples_per_s'] / base:.2f} "
                f"hit={r['cache_hit_rate']:.2f} "
                f"fetches={r['storage_fetches']}"))
        rows.append(("fig_tiered/summary",
                     f"spill speedup "
                     f"x{spill['samples_per_s'] / base:.2f} "
                     f"roundtrip_ok={spill['disk_roundtrip_checked']} "
                     f"json={path}"))
        if check:
            assert spill["samples_per_s"] > base, (
                f"DRAM+spill ({spill['samples_per_s']:.0f} sps) must "
                f"beat DRAM-only ({base:.0f} sps) at the constrained "
                f"DRAM budget")
            assert spill["disk_roundtrip_checked"] > 0, \
                "no disk-resident encoded entries to round-trip-check"
        return rows
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="assert DRAM+spill beats DRAM-only (CI)")
    args = ap.parse_args()
    for name, derived in run(full=args.full, check=args.check):
        print(f"{name},{derived}")
