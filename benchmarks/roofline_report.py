"""§Roofline: per-(arch x shape x mesh) three-term table from the dry-run
artifact (results/dryrun.json)."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def load(path: str = RESULTS):
    with open(path) as f:
        return json.load(f)


def rows_from(results, mesh: str = "single"):
    out = []
    for key, rec in sorted(results.items()):
        arch, shape, m = key.split("|")
        if m != mesh:
            continue
        if "skipped" in rec:
            out.append((f"roofline/{arch}/{shape}",
                        f"SKIP ({rec['skipped']})"))
            continue
        if "error" in rec:
            out.append((f"roofline/{arch}/{shape}", "ERROR"))
            continue
        out.append((
            f"roofline/{arch}/{shape}",
            f"compute={rec['t_compute'] * 1e3:.2f}ms "
            f"memory={rec['t_memory'] * 1e3:.2f}ms "
            f"collective={rec['t_collective'] * 1e3:.2f}ms "
            f"bottleneck={rec['bottleneck']} "
            f"frac={rec['roofline_fraction']:.2f} "
            f"useful={rec['useful_ratio']:.2f}"))
    return out


def run(full: bool = False):
    if not os.path.exists(RESULTS):
        return [("roofline/missing",
                 "run `python -m repro.launch.dryrun` first")]
    results = load()
    rows = rows_from(results, "single")
    ok = sum(1 for v in results.values()
             if "error" not in v and "skipped" not in v)
    errs = sum(1 for v in results.values() if "error" in v)
    rows.append(("roofline/dryrun_cells",
                 f"{ok} compiled ok, {errs} failed (both meshes)"))
    return rows


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
