"""Cross-job coalescing x lock-striping grid (ISSUE-10 tentpole).

K jobs with *identical* Zipfian request streams (same-seed samplers:
maximal working-set overlap, the worst case for duplicated preparation)
share one server and one token-bucket RemoteStorage, so the run is
bandwidth-bound and the win from single-flight coalescing is the
fetch-dedup factor rather than a host-dependent CPU effect.  Each
K in {1,2,4,8} runs the 2x2 feature grid:

  baseline          coalesce=False, lock_stripes=1  (the seed's layout)
  striped           coalesce=False, lock_stripes=8
  coalesce          coalesce=True,  lock_stripes=1
  coalesce+striped  coalesce=True,  lock_stripes=8

The baseline cells still *count* concurrent same-key productions (the
ProductionTable's observe mode), which is how ``--check`` proves the
claim pair: duplicates > 0 without coalescing, ~0 with it, and >= 1.3x
aggregate samples/s at K=4 for coalesce+striped over baseline.

Emits ``BENCH_concurrency.json``; registered as ``concurrency`` in
benchmarks/run.py.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Tuple

from benchmarks.common import write_bench_json
from repro.api import SenecaServer
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.workload.samplers import ZipfianSampler

CELLS: Tuple[Tuple[str, bool, int], ...] = (
    ("baseline", False, 1),
    ("striped", False, 8),
    ("coalesce", True, 1),
    ("coalesce+striped", True, 8),
)


def run_cell(k_jobs: int, coalesce: bool, stripes: int, *, n_samples: int,
             batch: int, batches: int, bandwidth: float,
             seed: int = 0) -> Dict:
    ds = tiny(n=n_samples)
    server = SenecaServer.for_dataset(
        ds, cache_frac=0.3, seed=seed,
        coalesce=coalesce, lock_stripes=stripes)
    storage = RemoteStorage(ds, bandwidth=bandwidth)

    # same-seed Zipfian streams: every job hammers the same hot head in
    # the same order, so misses collide *simultaneously* (the scenario
    # the cache alone cannot dedup — the second misser arrives while
    # the first production is still in flight)
    def same_seed_zipfian(n, bs, _job_seed, _base=seed):
        return ZipfianSampler(n, bs, seed=_base)

    pipes = [DSIPipeline(server.open_session(batch_size=batch,
                                             sampler=same_seed_zipfian),
                         storage, n_workers=4, seed=seed)
             for _ in range(k_jobs)]
    barrier = threading.Barrier(k_jobs + 1)
    errors: List[BaseException] = []

    def job(pipe: DSIPipeline) -> None:
        barrier.wait()
        try:
            for _ in range(batches):
                pipe.next_batch()
        except BaseException as e:        # noqa: BLE001 - surfaced below
            errors.append(e)

    threads = [threading.Thread(target=job, args=(p,)) for p in pipes]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.monotonic()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    if errors:
        raise errors[0]
    stats = server.service.stats()
    prod = stats.get("production",
                     {"led": 0, "coalesced": 0, "duplicates": 0,
                      "coalesce_wait_s": 0.0})
    for p in pipes:
        p.stop()
    server.close()
    samples = k_jobs * batches * batch
    return {
        "k_jobs": k_jobs,
        "coalesce": coalesce,
        "lock_stripes": stripes,
        "wall_s": wall,
        "agg_samples_per_s": samples / max(wall, 1e-9),
        "storage_fetches": storage.fetches,
        "cache_hit_rate": stats["cache_lookup_hit_rate"],
        "led": int(prod["led"]),
        "coalesced": int(prod["coalesced"]),
        "duplicates": int(prod["duplicates"]),
        "coalesce_wait_s": float(prod["coalesce_wait_s"]),
    }


def _check(results: Dict[int, Dict[str, Dict]]) -> None:
    """The acceptance gates: >= 1.3x aggregate throughput at 4+ jobs
    and duplicate productions driven to ~0 by coalescing."""
    k = max(k for k in results if k >= 4)
    base = results[k]["baseline"]
    best = results[k]["coalesce+striped"]
    speedup = best["agg_samples_per_s"] / base["agg_samples_per_s"]
    assert speedup >= 1.3, (
        f"K={k} coalesce+striped speedup {speedup:.2f}x < 1.3x over "
        f"single-lock no-coalescing baseline")
    assert best["coalesced"] > 0, "no production was ever coalesced"
    assert base["duplicates"] > 0, (
        "baseline saw no concurrent duplicate productions — the grid "
        "is not exercising overlapping misses")
    dup_budget = max(2, best["led"] // 50)
    assert best["duplicates"] <= dup_budget, (
        f"coalescing left {best['duplicates']} duplicate productions "
        f"(budget {dup_budget})")
    print(f"CHECK ok: K={k} speedup={speedup:.2f}x "
          f"coalesced={best['coalesced']} "
          f"duplicates {base['duplicates']} -> {best['duplicates']}")


def run(full: bool = False, check: bool = False) -> List[Tuple[str, str]]:
    knobs = dict(n_samples=3_072 if full else 384,
                 batch=32 if full else 16,
                 batches=24 if full else 10,
                 bandwidth=8e6 if full else 1.5e6)
    ks = (1, 2, 4, 8) if full else (1, 2, 4)
    results: Dict[int, Dict[str, Dict]] = {}
    for k in ks:
        results[k] = {name: run_cell(k, coalesce, stripes, **knobs)
                      for name, coalesce, stripes in CELLS}
    payload = {"config": {**{k: str(v) for k, v in knobs.items()},
                          "k_jobs": list(ks)},
               "grid": {str(k): cells for k, cells in results.items()}}
    path = write_bench_json("concurrency", payload)

    rows = []
    for k in ks:
        for name, _c, _s in CELLS:
            r = results[k][name]
            rows.append((
                f"fig_concurrency/K{k}/{name}",
                f"sps={r['agg_samples_per_s']:.0f} "
                f"fetches={r['storage_fetches']} "
                f"coalesced={r['coalesced']} dup={r['duplicates']}"))
    k = max(k for k in ks if k >= 4)
    speedup = (results[k]["coalesce+striped"]["agg_samples_per_s"]
               / results[k]["baseline"]["agg_samples_per_s"])
    rows.append((
        "fig_concurrency/summary",
        f"K={k} coalesce+striped speedup={speedup:.2f}x "
        f"dup {results[k]['baseline']['duplicates']}->"
        f"{results[k]['coalesce+striped']['duplicates']} json={path}"))
    if check:
        _check(results)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="assert the ISSUE-10 acceptance gates")
    args = ap.parse_args()
    for name, derived in run(full=args.full, check=args.check):
        print(f"{name},{derived}")
