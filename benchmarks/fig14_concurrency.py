"""Fig. 14 + Table 8: aggregate DSI throughput vs concurrent jobs.

OpenImages on the Azure server with a 400GB remote cache, 1..4 jobs.
Paper: Seneca outperforms Quiver 1.81x at 4 jobs and saturates the GPUs
(98% util) while baselines stay I/O- or CPU-bound; SHADE trails everything
(single-threaded).  Table 8's utilization columns map to the simulator's
per-resource busy fractions.
"""
from __future__ import annotations

from benchmarks.common import scaled, scaled_cache
from repro.api import (AZURE_NC96, DALI_CPU, DSISimulator, GB, MDP_ONLY,
                       MINIO, OPENIMAGES, PYTORCH, QUIVER, SENECA, SHADE,
                       SimJob)


def run(full: bool = False):
    ds = scaled(OPENIMAGES)
    cache = scaled_cache(400 * GB)
    job_counts = (1, 2, 4) if not full else (1, 2, 3, 4)
    rows = []
    at4 = {}
    for n_jobs in job_counts:
        line = {}
        for spec in (PYTORCH, DALI_CPU, MINIO, QUIVER, SHADE, MDP_ONLY,
                     SENECA):
            sim = DSISimulator(AZURE_NC96, ds, spec, cache_bytes=cache,
                               seed=5)
            r = sim.run([SimJob(j, gpu_rate=3500, batch_size=512, epochs=2)
                         for j in range(n_jobs)])
            line[spec.name] = r.throughput
            if n_jobs == max(job_counts):
                at4[spec.name] = r
        rows.append((
            f"fig14/jobs_{n_jobs}",
            " ".join(f"{k}={v:.0f}" for k, v in line.items())))
    ratio = at4["seneca"].throughput / at4["quiver"].throughput
    rows.append((f"fig14/seneca_vs_quiver_{max(job_counts)}jobs",
                 f"{ratio:.2f}x (paper: 1.81x)"))
    # Table 8: busy fractions at max concurrency
    for name in ("pytorch", "seneca"):
        r = at4[name]
        tot = max(r.makespan, 1e-9)
        util = {k: min(v / tot, 1.0) for k, v in r.busy.items()}
        rows.append((
            f"table8/{name}",
            f"gpu={util['gpu'] * 100:.0f}% cpu={util['cpu'] * 100:.0f}% "
            f"storage={util['storage'] * 100:.0f}%"))
    return rows


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
