"""Fig. 13: cache hit rate vs cached fraction of the dataset.

Three concurrent jobs on ImageNet-1K; paper: Seneca reaches 54% hit rate
with 20% of the dataset cached (11% over Quiver, the next best) and 66% at
40%; MINIO/MDP track the cached fraction.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import scaled
from repro.api import (AZURE_NC96, DSISimulator, IMAGENET_1K, MDP_ONLY,
                       MINIO, QUIVER, SENECA, SHADE, SimJob)

# the paper's Azure/ImageNet-1K MDP split (0-48-52): half the cache is the
# augmented tier, whose refcount-eviction churn is what lifts the hit rate
SENECA_PAPER = dataclasses.replace(SENECA, name="seneca",
                                   split_override=(0.0, 0.48, 0.52),
                                   mdp_split=False)


def run(full: bool = False):
    ds = scaled(IMAGENET_1K)
    fractions = (0.2, 0.4, 0.6, 0.8) if full else (0.2, 0.4)
    rows = []
    for frac in fractions:
        cache = frac * ds.n_total * ds.s_data  # encoded-equivalent sizing
        line = {}
        for spec in (MINIO, QUIVER, SHADE, MDP_ONLY, SENECA_PAPER):
            sim = DSISimulator(AZURE_NC96, ds, spec, cache_bytes=cache,
                               seed=4)
            r = sim.run([SimJob(j, gpu_rate=5000, batch_size=512, epochs=3)
                         for j in range(3)])
            line[spec.name] = r.hit_rate
        best_other = max(v for k, v in line.items() if k != "seneca")
        rows.append((
            f"fig13/cached_{int(frac * 100)}pct",
            " ".join(f"{k}={v:.2f}" for k, v in line.items())
            + f" | seneca_vs_next={line['seneca'] - best_other:+.2f} "
            f"(paper@20%: seneca=0.54, +0.11 vs quiver)"))
    return rows


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
