"""Fig. 3: fetch/preprocess/compute decomposition, encoded vs augmented
caches at two cache sizes (450GB vs 250GB on OpenImages).

Paper: at 450GB caching augmented data cuts preprocessing time 69.91% for
+34.85% fetch; at 250GB the preprocessing gain shrinks to 11.36% while
fetch rises 87.2% — i.e. the best form flips with capacity, motivating MDP.
"""
from __future__ import annotations

from benchmarks.common import scaled, scaled_cache
from repro.api import (AZURE_NC96, DSISimulator, GB, LoaderSpec,
                       OPENIMAGES, SimJob)

ENC = LoaderSpec("enc", split_override=(1.0, 0.0, 0.0),
                 cache_forms=("encoded",), sampling="random",
                 evict_refcount=False)
AUG = LoaderSpec("aug", split_override=(0.0, 0.0, 1.0),
                 cache_forms=("augmented",), sampling="random",
                 evict_refcount=False)


def run(full: bool = False):
    ds = scaled(OPENIMAGES)
    rows = []
    decomp = {}
    for cache_gb in (450, 250):
        cache = scaled_cache(cache_gb * GB)
        for spec in (ENC, AUG):
            sim = DSISimulator(AZURE_NC96, ds, spec, cache_bytes=cache,
                               seed=7)
            r = sim.run([SimJob(0, gpu_rate=2500, batch_size=512,
                                epochs=2)])
            fetch = r.busy["storage"] + r.busy["cache_bw"] + r.busy["nic"]
            decomp[(cache_gb, spec.name)] = (fetch, r.busy["cpu"],
                                             r.busy["gpu"])
            rows.append((
                f"fig3/{cache_gb}gb/{spec.name}",
                f"fetch={fetch:.0f}s preprocess={r.busy['cpu']:.0f}s "
                f"compute={r.busy['gpu']:.0f}s epoch={r.makespan / 2:.0f}s"))
    for cache_gb in (450, 250):
        fe, pe, _ = decomp[(cache_gb, "enc")]
        fa, pa, _ = decomp[(cache_gb, "aug")]
        rows.append((
            f"fig3/{cache_gb}gb/delta",
            f"preprocess {100 * (pa - pe) / max(pe, 1e-9):+.1f}% "
            f"fetch {100 * (fa - fe) / max(fe, 1e-9):+.1f}% "
            f"(paper 450GB: -69.91% / +34.85%; 250GB: -11.36% / +87.2%)"))
    return rows


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
