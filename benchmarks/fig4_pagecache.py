"""Fig. 4: (a) page-cache LRU decay with dataset size; (b) preprocessing
redundancy across concurrent jobs with/without a shared cache.

Paper: growing 400->600GB costs PyTorch 67.34% DSI throughput (LRU churn);
4 concurrent jobs run 7.16M preprocess ops over 1.7M samples without
sharing, 3.7x fewer with a shared preprocessed cache.
"""
from __future__ import annotations

from dataclasses import replace

from benchmarks.common import scaled_cache
from repro.api import (AZURE_NC96, DatasetProfile, DSISimulator, GB, KB,
                       LoaderSpec, PYTORCH, SENECA, SimJob)


def run(full: bool = False):
    rows = []
    # (a) DSI throughput vs dataset size under the page-cache LRU
    dram = scaled_cache(512 * GB)
    tp = {}
    for gb in (300, 400, 500, 600):
        n = int(gb * GB / (315.84 * KB) / 10)
        ds = DatasetProfile(f"oi-{gb}gb", n, 315.84 * KB)
        sim = DSISimulator(AZURE_NC96, ds, PYTORCH, cache_bytes=dram,
                           seed=8)
        r = sim.run([SimJob(0, gpu_rate=9000, batch_size=512, epochs=2)])
        tp[gb] = r.throughput
        rows.append((f"fig4a/pytorch_{gb}gb", f"{r.throughput:.0f}/s"))
    rows.append(("fig4a/degradation_400to600",
                 f"{100 * (1 - tp[600] / tp[400]):.1f}% (paper: 67.34%)"))

    # (b) preprocessing ops: 4 independent pipelines vs shared cache
    ds = DatasetProfile("oi-4b", 170_000, 315.84 * KB)
    ops = {}
    for spec in (PYTORCH, SENECA):
        sim = DSISimulator(AZURE_NC96, ds, spec,
                           cache_bytes=scaled_cache(350 * GB), seed=8)
        r = sim.run([SimJob(j, gpu_rate=9000, batch_size=512, epochs=1)
                     for j in range(4)])
        ops[spec.name] = r.preprocess_ops
        rows.append((f"fig4b/{spec.name}_preprocess_ops",
                     f"{r.preprocess_ops:,}"))
    rows.append(("fig4b/reduction",
                 f"{ops['pytorch'] / max(ops['seneca'], 1):.1f}x fewer "
                 f"(paper: 3.7x)"))
    return rows


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
