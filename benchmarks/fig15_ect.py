"""Fig. 15: first/stable epoch completion times across datasets/loaders.

Two concurrent jobs per cell; paper highlights: Seneca's stable ECT for
ResNet-50/ImageNet-1K is 3.45x faster than MINIO (15a); on OpenImages/AWS
Seneca cuts stable ECT up to 87% vs DALI-CPU (15b); on ImageNet-22K the
page-cache loaders collapse and Seneca still wins ~29% (15c).
"""
from __future__ import annotations

from benchmarks.common import scaled, scaled_cache
from repro.api import (AWS_P3, AZURE_NC96, DALI_CPU, DSISimulator, GB,
                       IMAGENET_1K, IMAGENET_22K, MINIO, OPENIMAGES,
                       PYTORCH, QUIVER, SENECA, SimJob)

CELLS = [
    ("15a", AZURE_NC96, IMAGENET_1K, 400 * GB),
    ("15b", AWS_P3, OPENIMAGES, 400 * GB),
    ("15c", AZURE_NC96, IMAGENET_22K, 400 * GB),
]


def run(full: bool = False):
    rows = []
    for tag, hw, ds_full, cache_full in CELLS:
        scale = 10 if tag != "15c" else 40
        ds = scaled(ds_full, scale)
        cache = scaled_cache(cache_full, scale)
        stable = {}
        first = {}
        for spec in (PYTORCH, DALI_CPU, MINIO, QUIVER, SENECA):
            sim = DSISimulator(hw, ds, spec, cache_bytes=cache, seed=6)
            r = sim.run([SimJob(j, gpu_rate=6000, batch_size=512, epochs=3)
                         for j in range(2)])
            stable[spec.name] = sum(r.stable_epoch_s.values()) / 2
            first[spec.name] = sum(r.first_epoch_s.values()) / 2
        best_other = min(v for k, v in stable.items() if k != "seneca")
        rows.append((
            f"fig15/{tag}/{ds_full.name}",
            " ".join(f"{k}={v:.0f}s" for k, v in stable.items())
            + f" | seneca_speedup_vs_next="
            f"{best_other / max(stable['seneca'], 1e-9):.2f}x"))
        rows.append((
            f"fig15/{tag}/first_epoch",
            " ".join(f"{k}={v:.0f}s" for k, v in first.items())))
    return rows


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
