"""Open-loop serving: p99 tail latency vs offered load, with and without
SLO-aware admission control.

Closed-loop benchmarks (fig_live_makespan) measure makespan — the
pipeline can never fall behind, only slow down.  This one drives the
live stack *open-loop*: Poisson request arrivals replayed on a
:class:`~repro.workload.clock.VirtualClock` (byte-reproducible
schedules, storage stalls charged through the clock-aware token bucket,
modeled decode/augment service costs), swept across offered rates from
under- to over-load.  At each rate the same arrival trace runs twice:

* **uncontrolled** — no SLO: every request queues, so past the capacity
  knee the backlog (and p99) grows with the trace length;
* **controlled** — :class:`~repro.api.SLO` admission: requests are
  degraded (skip augment), served encoded, or shed once the estimated
  queue wait crosses the target's fractions — p99 stays bounded and
  every decision is counted.

Emits ``BENCH_open_loop.json``; ``--check`` asserts (a) controlled p99 <
uncontrolled p99 at the overload point with shed/degraded requests
actually counted, and (b) the full per-request latency vector is
identical across two fresh VirtualClock runs.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from benchmarks.common import write_bench_json
from repro.api import SLO, SenecaServer
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.workload import (OpenLoopGenerator, VirtualClock,
                            poisson_arrivals)

# modeled per-request service costs (seconds) charged on the virtual
# clock: with 2 workers the service capacity is 2 / 0.007 ~ 285 req/s
PHASE_COSTS = {"decode": 0.004, "augment": 0.003}
N_WORKERS = 2
SLO_CFG = SLO(p99_target_s=0.05, max_queue=64)


def run_point(rate: float, *, n_requests: int, n_samples: int,
              controlled: bool, seed: int = 0) -> Dict:
    """One (rate, admission-mode) cell: fresh server + clock + trace."""
    ds = tiny(n=n_samples)
    server = SenecaServer.for_dataset(ds, cache_frac=0.3, seed=seed)
    clock = VirtualClock()
    storage = RemoteStorage(ds, bandwidth=8e6, clock=clock)
    gen = OpenLoopGenerator(server, storage, clock=clock,
                            slo=SLO_CFG if controlled else None,
                            n_workers=N_WORKERS, seed=seed,
                            phase_costs=PHASE_COSTS)
    arrivals = poisson_arrivals(rate, n=n_requests, seed=seed + 17)
    res = gen.run(arrivals)
    server.close()
    out = {
        "rate": rate,
        "controlled": controlled,
        "counts": dict(res.counts),
        "latency_s": res.percentiles(),
        "phase_latency_s": res.phase_percentiles(),
        "makespan_s": res.makespan_s,
        "latencies": [round(r.total_s, 9) for r in res.requests],
    }
    return out


def run(full: bool = False) -> List[Tuple[str, str]]:
    n_requests = 1200 if full else 400
    n_samples = 512 if full else 128
    rates = (100, 250, 400, 600) if full else (150, 450)
    overload = rates[-1]

    sweep: List[Dict] = []
    for rate in rates:
        for controlled in (False, True):
            sweep.append(run_point(rate, n_requests=n_requests,
                                   n_samples=n_samples,
                                   controlled=controlled))
    # determinism probe: replay the overload/controlled cell fresh and
    # compare the full per-request latency vector bit-for-bit
    again = run_point(overload, n_requests=n_requests,
                      n_samples=n_samples, controlled=True)
    first = next(p for p in sweep
                 if p["rate"] == overload and p["controlled"])
    deterministic = first["latencies"] == again["latencies"]

    by_rate: Dict[float, Dict[str, Dict]] = {}
    for p in sweep:
        by_rate.setdefault(p["rate"], {})[
            "controlled" if p["controlled"] else "uncontrolled"] = p
    over = by_rate[overload]
    payload = {
        "config": {"n_requests": n_requests, "n_samples": n_samples,
                   "n_workers": N_WORKERS, "phase_costs": PHASE_COSTS,
                   "slo": {"p99_target_s": SLO_CFG.p99_target_s,
                           "max_queue": SLO_CFG.max_queue},
                   "rates": list(rates), "overload_rate": overload},
        "deterministic": deterministic,
        "sweep": [{k: v for k, v in p.items() if k != "latencies"}
                  for p in sweep],
        "overload": {
            "uncontrolled_p99_s": over["uncontrolled"]["latency_s"]["p99"],
            "controlled_p99_s": over["controlled"]["latency_s"]["p99"],
            "controlled_counts": over["controlled"]["counts"],
        },
    }
    path = write_bench_json("open_loop", payload)

    rows = []
    for rate in rates:
        u, c = by_rate[rate]["uncontrolled"], by_rate[rate]["controlled"]
        rows.append((
            f"fig_open_loop/rate{rate:.0f}",
            f"p99 uncontrolled={u['latency_s']['p99'] * 1e3:.1f}ms "
            f"controlled={c['latency_s']['p99'] * 1e3:.1f}ms "
            f"shed={c['counts']['shed']} "
            f"degraded={c['counts']['degraded']}"))
    rows.append(("fig_open_loop/deterministic",
                 f"replay_identical={deterministic} json={path}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="assert controlled p99 < uncontrolled p99 at "
                         "overload and VirtualClock determinism")
    args = ap.parse_args()
    out_rows = run(full=args.full)
    for name, derived in out_rows:
        print(f"{name},{derived}")
    if args.check:
        import json
        with open("BENCH_open_loop.json") as f:
            bench = json.load(f)
        over = bench["overload"]
        u99, c99 = (float(over["uncontrolled_p99_s"]),
                    float(over["controlled_p99_s"]))
        counts = over["controlled_counts"]
        assert c99 < u99, (
            f"admission control did not hold p99 below the uncontrolled "
            f"baseline at overload ({c99:.4f}s >= {u99:.4f}s)")
        assert counts["shed"] + counts["degraded"] + counts["encoded"] > 0, \
            f"overload run never shed or degraded a request: {counts}"
        assert bench["deterministic"], (
            "VirtualClock replay produced different per-request latencies")
        print(f"CHECK OK: overload p99 {c99 * 1e3:.1f}ms (controlled) < "
              f"{u99 * 1e3:.1f}ms (uncontrolled), "
              f"shed={counts['shed']} degraded={counts['degraded']}, "
              f"deterministic replay")
