"""Sharded data plane: disaggregated cache shards vs one threaded process.

Seneca's paper deployment is one cache service per training node; this
benchmark measures the tf.data-service-style disaggregation added in
``repro.service`` — N :class:`~repro.service.shard.CacheShard` workers
behind a consistent-hash :class:`~repro.service.router.ShardRouter`,
each producing (fetch → decode → augment) and caching its own key range.

Three sections, all against the same synthetic dataset:

* ``determinism`` — the same 2-job VirtualClock trace on ``shards=1``
  and ``shards=2`` sim transports must yield identical per-job sample-id
  sequences (the sim transport runs every shard call synchronously on
  the calling job's turn), and two fresh ``shards=2`` runs must be
  byte-identical to each other.
* ``paced`` — ingest throughput when each shard node brings its own
  storage NIC (per-shard token-bucket bandwidth).  Baseline: the classic
  single-process threaded stack (sim transport, 4 worker threads, ONE
  NIC shared).  Disaggregated: process transport at 1/2/4 shards, one
  NIC per shard.  This is the paper's disaggregation story and scales
  with shard count even on a single-core host, because the bottleneck
  is paced I/O, not CPU.
* ``cpu`` — ingest throughput on a GIL-heavy decode
  (:class:`~repro.data.synthetic.DecodeHeavyDataset`): process shards
  sidestep the GIL, so this section scales with *physical cores* — the
  JSON records ``ncpu`` so a 1-core CI box reporting ~1x is read as
  expected, not as a regression.

Emits ``BENCH_sharded.json``.  ``--check`` (the CI smoke gate) runs the
sim-transport sections only on a small trace: determinism asserts plus a
2-NIC-vs-1-NIC paced sanity ratio.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

from benchmarks.common import write_bench_json
from repro.api import JobSpec, SenecaServer, ShardedCache
from repro.data.storage import RemoteStorage
from repro.data.synthetic import DecodeHeavyDataset, tiny
from repro.workload.runner import deterministic_runner

#: manual split for every run in this file: per-shard MDP solves are
#: covered by tests; here they would let the 1-shard and N-shard planes
#: pick different splits and muddy both the determinism comparison and
#: the throughput ratios
SPLIT = (0.2, 0.3, 0.5)
NIC_BYTES_PER_S = 6e6


def _workload_ids(ds, shards: int, seed: int = 0) -> Dict[str, List[int]]:
    """Per-job sample-id sequences for one deterministic 2-job trace."""
    cache_bytes = 2 * ds.n_samples * ds.augmented_bytes()
    server = SenecaServer.for_dataset(ds, cache_bytes=cache_bytes,
                                      split=SPLIT, seed=seed, shards=shards)
    runner = deterministic_runner(server, RemoteStorage(ds), seed=seed)
    res = runner.run([
        JobSpec("a", arrival_s=0.0, epochs=2, batch_size=16, gpu_rate=1000),
        JobSpec("b", arrival_s=0.05, epochs=1, batch_size=8, gpu_rate=500),
    ], timeout=300)
    ids = {j.spec.name: list(j.sample_ids) for j in res.jobs}
    server.close()
    return ids


def _ingest_rate(ds, *, shards: int, transport: str,
                 total_bandwidth: float, n_ids: int) -> Dict:
    """Samples/s for one cold ``ingest`` sweep over ``n_ids`` samples.

    ``total_bandwidth`` is the aggregate storage bandwidth of the whole
    plane (the client gives each shard a 1/N cut) — so a single-machine
    baseline passes one NIC and a disaggregated N-node plane passes N.
    """
    cache = ShardedCache(
        2 * ds.n_samples * ds.augmented_bytes(),
        SPLIT, shards=shards, transport=transport, seed=0,
        dataset=ds, storage_bandwidth=total_bandwidth)
    try:
        ids = list(range(n_ids))
        t0 = time.monotonic()
        produced = cache.ingest(ids, epoch_tag=0)
        dt = time.monotonic() - t0
        assert produced == n_ids, (produced, n_ids)
        per_shard = [s["produced"] for s in cache.shard_stats()]
    finally:
        cache.close()
    return {"shards": shards, "transport": transport,
            "samples_per_s": n_ids / dt, "ingest_s": dt,
            "nics": round(total_bandwidth / NIC_BYTES_PER_S, 2),
            "produced_per_shard": per_shard}


def _produce_parity(ds) -> int:
    """Process-transport produce must match the in-process computation
    byte for byte (PayloadRef/memmap shipping is lossless)."""
    import numpy as np

    from repro.data.augment import augment_np
    from repro.service.shard import produce_seed

    cache = ShardedCache(ds.n_samples * ds.augmented_bytes(), SPLIT,
                         shards=2, transport="process", seed=0, dataset=ds)
    try:
        checked = 0
        for sid in (0, 3, 11):
            out = np.asarray(cache.produce(sid, epoch_tag=1))
            img = ds.decode(ds.encoded(sid), sid)
            ref = augment_np(img, ds.crop_hw,
                             np.random.default_rng(produce_seed(1, sid)))
            assert np.array_equal(out, ref), f"produce parity, sid={sid}"
            checked += 1
    finally:
        cache.close()
    return checked


def run(full: bool = False, check: bool = False) -> List[Tuple[str, str]]:
    rows: List[Tuple[str, str]] = []
    payload: Dict = {"ncpu": os.cpu_count(),
                     "nic_bytes_per_s": NIC_BYTES_PER_S}

    # -- determinism: shards=1 vs shards=2, and run-to-run ------------
    ds = tiny(n=96 if check else 128)
    one = _workload_ids(ds, shards=1)
    two = _workload_ids(ds, shards=2)
    two_again = _workload_ids(ds, shards=2)
    assert two == two_again, \
        "two fresh shards=2 sim runs diverged (determinism broken)"
    assert one == two, \
        "shards=2 sim run diverged from the shards=1 sequence"
    payload["determinism"] = {
        "jobs": sorted(one),
        "samples": {k: len(v) for k, v in one.items()},
        "shards1_eq_shards2": True, "rerun_identical": True}
    rows.append(("fig_sharded/determinism",
                 f"jobs={len(one)} samples={sum(map(len, one.values()))} "
                 f"1shard==2shard=ok rerun=ok"))

    # -- paced: per-shard NIC scaling ---------------------------------
    n_ids = 64 if check else (512 if full else 256)
    paced: List[Dict] = []
    if check:
        # CI smoke: sim transport only — threads still pace their own
        # per-shard token buckets, so the NIC-scaling effect is visible
        # without spawning processes
        base = _ingest_rate(ds, shards=2, transport="sim",
                            total_bandwidth=NIC_BYTES_PER_S, n_ids=n_ids)
        disagg = _ingest_rate(ds, shards=2, transport="sim",
                              total_bandwidth=2 * NIC_BYTES_PER_S,
                              n_ids=n_ids)
        paced = [base, disagg]
        speedup = disagg["samples_per_s"] / base["samples_per_s"]
        assert speedup >= 1.2, \
            f"2 NICs only {speedup:.2f}x over 1 NIC (pacing broken?)"
    else:
        base = _ingest_rate(ds, shards=4, transport="sim",
                            total_bandwidth=NIC_BYTES_PER_S, n_ids=n_ids)
        paced = [base]
        for n in (1, 2, 4):
            paced.append(_ingest_rate(
                ds, shards=n, transport="process",
                total_bandwidth=n * NIC_BYTES_PER_S, n_ids=n_ids))
        speedup = paced[-1]["samples_per_s"] / base["samples_per_s"]
        assert speedup >= 1.5, (
            f"4 process shards with 4 NICs only {speedup:.2f}x over the "
            f"1-NIC threaded single-process baseline")
    payload["paced"] = paced
    for r in paced:
        rows.append((f"fig_sharded/paced/{r['transport']}-{r['shards']}"
                     f"shard-{r['nics']}nic",
                     f"sps={r['samples_per_s']:.0f} "
                     f"x{r['samples_per_s'] / paced[0]['samples_per_s']:.2f}"))

    # -- cpu: GIL-heavy decode across processes (skipped in --check) --
    if not check:
        heavy = DecodeHeavyDataset(
            "decode-heavy", ds.n_samples, ds.mean_encoded_bytes,
            image_hw=ds.image_hw, crop_hw=ds.crop_hw,
            n_classes=ds.n_classes,
            decode_work=65_536 if full else 24_576)
        n_cpu_ids = 256 if full else 128
        cpu_rows = [_ingest_rate(heavy, shards=4, transport="sim",
                                 total_bandwidth=0, n_ids=n_cpu_ids)]
        for n in (1, 2, 4):
            cpu_rows.append(_ingest_rate(heavy, shards=n,
                                         transport="process",
                                         total_bandwidth=0,
                                         n_ids=n_cpu_ids))
        payload["cpu"] = cpu_rows
        for r in cpu_rows:
            rows.append((f"fig_sharded/cpu/{r['transport']}-{r['shards']}"
                         f"shard",
                         f"sps={r['samples_per_s']:.0f} x"
                         f"{r['samples_per_s'] / cpu_rows[0]['samples_per_s']:.2f}"
                         f" ncpu={os.cpu_count()}"))
        payload["produce_parity_checked"] = _produce_parity(ds)

    path = write_bench_json("sharded", payload)
    rows.append(("fig_sharded/summary",
                 f"paced speedup x{speedup:.2f} ncpu={os.cpu_count()} "
                 f"json={path}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="sim-transport smoke: determinism + NIC pacing")
    args = ap.parse_args()
    for name, derived in run(full=args.full, check=args.check):
        print(f"{name},{derived}")
