"""Fig. 8: DSI model validation — closed-form model vs simulator.

The paper varies dataset size 64->512GB at a 64GB cache for six fixed
splits on four hardware configs, and reports Pearson >= 0.90 between model
and measurement.  Our "measurement" is the mechanistic simulator (same
hardware constants, independent cache/sampler mechanics).
"""
from __future__ import annotations

import numpy as np
from dataclasses import replace

from repro.api import (DatasetProfile, DSISimulator, GB, JobProfile, KB,
                       LoaderSpec, SimJob, VALIDATION_PROFILES,
                       dsi_throughput)

SPLITS = [(1.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, 1.0),
          (0.5, 0.5, 0.0), (0.5, 0.0, 0.5), (0.0, 0.5, 0.5)]

S_DATA = 114.62 * KB          # replicated ImageNet-1K samples (paper setup)


def run(full: bool = False):
    rows = []
    sizes_gb = [64, 128, 256, 384, 512] if full else [64, 128, 256, 448]
    scale = 1 if full else 20
    cache = 64 * GB / scale
    min_corr = 1.0
    for hw in VALIDATION_PROFILES:
        hw = replace(hw, s_cache=cache)
        for split in SPLITS:
            model_v, sim_v = [], []
            for gb in sizes_gb:
                n = int(gb * GB / S_DATA / scale)
                ds = DatasetProfile(f"in1k-{gb}gb", n, S_DATA)
                model_v.append(float(dsi_throughput(
                    hw, ds, JobProfile(), *split).overall))
                spec = LoaderSpec(
                    "fixed", split_override=split,
                    cache_forms=("encoded", "decoded", "augmented"),
                    sampling="random", evict_refcount=False)
                # overlap=False reproduces Eq. 9's per-form serial service
                # discipline (the overlapped-pipeline divergence on pure-
                # augmented caches is reported in EXPERIMENTS.md §Fig8)
                sim = DSISimulator(hw, ds, spec, cache_bytes=cache, seed=1,
                                   overlap=False)
                r = sim.run([SimJob(0, gpu_rate=hw.t_gpu,
                                    batch_size=512, epochs=3)])
                # steady-state: warm-epoch throughput (the model has no
                # cold-start term; paper's "stable ECT" measurement)
                stable = r.stable_epoch_s.get(0, r.makespan / 3)
                sim_v.append(n / max(stable, 1e-9))
            mv, sv = np.asarray(model_v), np.asarray(sim_v)
            cv_m = np.std(mv) / max(np.mean(mv), 1e-9)
            cv_s = np.std(sv) / max(np.mean(sv), 1e-9)
            if cv_m < 0.02 and cv_s < 0.05:
                corr = 1.0          # both flat: trivially consistent
                flat = " (flat)"
            else:
                corr = float(np.corrcoef(mv, sv)[0, 1])
                flat = ""
            min_corr = min(min_corr, corr)
            lab = "-".join(str(int(x * 100)) for x in split)
            rel = float(np.mean(np.abs(sv - mv) / np.maximum(mv, 1e-9)))
            rows.append((f"fig8/{hw.name}/{lab}",
                         f"pearson={corr:.3f}{flat} rel_err={rel:.2f}"))
    rows.append(("fig8/summary",
                 f"min_pearson={min_corr:.3f} (paper: >=0.90)"))
    return rows


if __name__ == "__main__":
    for name, derived in run():
        print(name, "|", derived)
