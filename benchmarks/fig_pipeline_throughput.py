"""Pipeline executor throughput: per-sample map vs stage-parallel vs
stage-parallel with Pallas-batched augmentation.

The paper's cache partitioning only pays off when the DSI pipeline can
saturate the cache it was given; this benchmark measures the ingestion
side on the *live* threaded stack.  Three configurations over identical
datasets/storage (token-bucket bandwidth, so storage stalls are real):

* ``per-sample`` — the seed executor: fetch->decode->augment serially
  per sample inside a worker pool, a full barrier per batch;
* ``stage-parallel`` — the queue-fed stage executor (bounded queues,
  elastic telemetry-sized worker groups, batch-granular admission):
  batch N+1's storage fetches overlap batch N's decode/augment, no
  per-batch barrier;
* ``stage-parallel+pallas`` — same executor, augment stage running the
  fused Pallas crop/flip/normalize kernel on whole groups.

Measurement: the dataset is sized so the whole run stays inside the
cold first epoch (one regime — crossing into epoch 2 flips the workload
to cache-hit-dominated and the numbers stop being comparable), and each
mode reports the **median of three consecutive timed windows** to shrug
off noisy-neighbor CPU on shared runners.

Emits ``BENCH_pipeline.json`` (benchmarks/common.write_bench_json) with
per-mode samples/s (median + windows), stage time breakdowns and queue
occupancy gauges, plus the usual ``name,us,derived`` rows for run.py.
``--check`` asserts the stage-parallel executor beats the per-sample
baseline (the CI smoke gate).
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List, Tuple

from benchmarks.common import write_bench_json
from repro.api import SenecaServer
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny

MODES: Tuple[Tuple[str, str, str], ...] = (
    ("per-sample", "per-sample", "numpy"),
    ("stage-parallel", "stage-parallel", "numpy"),
    ("stage-parallel+pallas", "stage-parallel", "pallas"),
)


def run_mode(executor: str, augment_backend: str, *, n_samples: int,
             batch: int, windows: int, window_batches: int, warmup: int,
             bandwidth: float, n_workers: int, seed: int = 0) -> Dict:
    ds = tiny(n=n_samples)
    server = SenecaServer.for_dataset(ds, cache_frac=0.25, seed=seed,
                                      augment_backend=augment_backend)
    storage = RemoteStorage(ds, bandwidth=bandwidth)
    pipe = DSIPipeline(server.open_session(batch_size=batch), storage,
                       n_workers=n_workers, prefetch=2, executor=executor,
                       seed=seed)
    for _ in range(warmup):       # warm jit traces, EWMAs, worker plans
        pipe.next_batch()
    rates = []
    for _ in range(windows):
        t0 = time.monotonic()
        for _ in range(window_batches):
            pipe.next_batch()
        rates.append(window_batches * batch / (time.monotonic() - t0))
    stats = server.stats()
    tel = stats["telemetry"]
    result = {
        "executor": executor,
        "augment_backend": stats["augment_backend"],
        "samples_per_s": statistics.median(rates),
        "window_samples_per_s": [round(r, 1) for r in rates],
        "stage_times_s": pipe.times.as_dict(),
        "cache_hit_rate": stats["cache_lookup_hit_rate"],
        "ods_hit_rate": stats["ods_hit_rate"],
        "storage_fetches": storage.fetches,
        "queue_occupancy": tel["queue_occupancy"],
        "refill_errors": stats["refill_errors"],
    }
    pipe.stop()
    server.close()
    return result


def run(full: bool = False, check: bool = False) -> List[Tuple[str, str]]:
    knobs = dict(n_samples=8_192 if full else 2_048, batch=16,
                 windows=3, window_batches=24 if full else 12,
                 warmup=4, bandwidth=8e6, n_workers=4)
    results = {label: run_mode(executor, backend, **knobs)
               for label, executor, backend in MODES}

    def sps(label):
        return results[label]["samples_per_s"]

    if check and sps("stage-parallel") <= sps("per-sample"):
        # one retry: a noisy-neighbor burst on a shared CI runner can
        # sink one mode's whole 3-window median; re-measure both modes
        # back-to-back before declaring a regression.  The artifact and
        # the rows below are built from the retried numbers, so the
        # published JSON never contradicts a passing gate.
        results["per-sample"] = run_mode("per-sample", "numpy", **knobs)
        results["stage-parallel"] = run_mode("stage-parallel", "numpy",
                                             **knobs)
    payload = {"config": {k: str(v) for k, v in knobs.items()}, **results}
    path = write_bench_json("pipeline", payload)

    rows = []
    base = sps("per-sample")
    for label, r in results.items():
        rows.append((
            f"fig_pipeline/{label}",
            f"sps={r['samples_per_s']:.0f} "
            f"x{r['samples_per_s'] / base:.2f} "
            f"windows={r['window_samples_per_s']}"))
    sp = sps("stage-parallel")
    rows.append(("fig_pipeline/summary",
                 f"stage-parallel speedup x{sp / base:.2f} json={path}"))
    if check:
        assert sp > base, (
            f"stage-parallel ({sp:.0f} sps) must beat the per-sample "
            f"baseline ({base:.0f} sps)")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="assert stage-parallel beats per-sample (CI)")
    args = ap.parse_args()
    for name, derived in run(full=args.full, check=args.check):
        print(f"{name},{derived}")
