"""Live multi-job makespan: shared Seneca cache vs per-job private naive.

The paper's headline number (45.23% makespan reduction for a 12-job
trace, Fig. 10) is reproduced in this repo by the fluid simulator
(``fig10_makespan.py``).  This benchmark runs the same *shape* of
experiment on the live threaded stack instead: a staggered-arrival trace
of jobs, each an independent :class:`~repro.data.pipeline.DSIPipeline`
with a rate-limited consumer emulating GPU ingest
(:class:`~repro.workload.runner.WorkloadRunner`), against

* **shared** — one :class:`~repro.api.SenecaServer` (ODS sampling, MDP
  split, refcount eviction): all sessions share one cache, so one job's
  augmentations serve the others (the paper's concurrency claim);
* **private** — a per-job server with 1/N of the cache bytes, naive
  sampling, encoded-only LRU (the PyTorch-like page-cache baseline).

Both modes contend for the same token-bucket storage bandwidth.  Scaled
to CPU-runnable size (5 jobs, tiny dataset); ratios are what matter.

Emits ``BENCH_live_makespan.json``; ``--check`` asserts the shared-cache
makespan beats the private baseline (reduction > 0) on the live stack.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from benchmarks.common import write_bench_json
from repro.api import JobSpec, SenecaServer, WorkloadRunner
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny

# GPU ingest rates (samples/s): mixed model sizes like the Fig. 10 trace
JOB_RATES = (900, 500, 700, 900, 600)
ARRIVAL_STEP_S = 0.3


def _trace(epochs: int, batch: int) -> List[JobSpec]:
    return [JobSpec(f"job{i}", arrival_s=i * ARRIVAL_STEP_S,
                    epochs=epochs, batch_size=batch, gpu_rate=rate,
                    n_workers=2)
            for i, rate in enumerate(JOB_RATES)]


def run_mode(mode: str, *, n_samples: int, epochs: int, batch: int,
             cache_frac: float, bandwidth: float, seed: int = 0) -> Dict:
    ds = tiny(n=n_samples)
    total_cache = int(cache_frac * n_samples * ds.augmented_bytes())
    storage = RemoteStorage(ds, bandwidth=bandwidth)
    if mode == "shared":
        server = SenecaServer.for_dataset(ds, cache_bytes=total_cache,
                                          seed=seed)
        runner = WorkloadRunner(server, storage, record_ids=False,
                                seed=seed)
    else:                         # per-job private naive (PyTorch-like)
        server = None

        def factory(spec: JobSpec) -> SenecaServer:
            return SenecaServer.for_dataset(
                ds, cache_bytes=total_cache // len(JOB_RATES), seed=seed,
                use_ods=False, split=(1.0, 0.0, 0.0), eviction="lru")
        runner = WorkloadRunner(server_factory=factory, storage=storage,
                                record_ids=False, seed=seed)
    res = runner.run(_trace(epochs, batch), timeout=600)
    out = {
        "mode": mode,
        "makespan_s": res.makespan,
        "wall_s": res.wall_s,
        "total_samples": res.total_samples,
        "storage_fetches": storage.fetches,
        "per_job_s": {j.spec.name: round(j.duration_s, 3)
                      for j in res.jobs},
        "epochs_completed": {j.spec.name: j.epochs_completed
                             for j in res.jobs},
    }
    if mode == "shared":
        out["ods_hit_rate"] = res.stats["ods_hit_rate"]
        out["substitutions"] = res.stats["substitutions"]
        out["partition"] = res.stats["partition"]
        server.close()
    return out


def run(full: bool = False) -> List[Tuple[str, str]]:
    # bandwidth is deliberately the scarce resource (the paper's NFS
    # bottleneck): the private baseline fetches ~2.5x the bytes, so its
    # makespan carries a hardware floor the shared cache avoids — which
    # keeps the --check assertion robust against CPU scheduling noise
    # on small CI runners
    knobs = dict(n_samples=1_536 if full else 384,
                 epochs=3 if full else 2, batch=16,
                 cache_frac=0.4, bandwidth=12e6)
    results = {mode: run_mode(mode, **knobs)
               for mode in ("shared", "private")}
    shared, private = results["shared"], results["private"]
    reduction = 1 - shared["makespan_s"] / private["makespan_s"]
    payload = {"config": {k: str(v) for k, v in knobs.items()},
               "reduction": reduction, **results}
    path = write_bench_json("live_makespan", payload)

    rows = [(f"fig_live_makespan/{m}",
             f"makespan={r['makespan_s']:.2f}s "
             f"fetches={r['storage_fetches']}")
            for m, r in results.items()]
    rows.append((
        "fig_live_makespan/reduction",
        f"{reduction * 100:.1f}% (live stack; paper sim: 45.23%) "
        f"hit={shared['ods_hit_rate']:.3f} json={path}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="assert shared-cache makespan < private baseline")
    args = ap.parse_args()
    out_rows = run(full=args.full)
    for name, derived in out_rows:
        print(f"{name},{derived}")
    if args.check:
        import json
        with open("BENCH_live_makespan.json") as f:
            bench = json.load(f)
        red = float(bench["reduction"])
        assert red > 0, (
            f"shared-cache makespan did not beat the private baseline "
            f"(reduction={red:.3f})")
        print(f"CHECK OK: live shared-cache reduction {red:.1%} > 0")
