"""Shared benchmark scaffolding.

Simulated datasets are weak-scaled (1/10 sample count, 1/10 cache bytes) so
the full harness runs in minutes on one CPU core; throughput *ratios* are
scale-invariant because every resource demand is per-sample.  ``--full``
runs paper-size populations.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import replace
from typing import Callable, List, Tuple

from repro.core.perf_model import (AWS_P3, AZURE_NC96, IN_HOUSE,
                                   DatasetProfile, GB)

SCALE = 10


def scaled(ds: DatasetProfile, scale: int = SCALE) -> DatasetProfile:
    return replace(ds, name=f"{ds.name}/{scale}",
                   n_total=ds.n_total // scale)


def scaled_cache(bytes_: float, scale: int = SCALE) -> float:
    return bytes_ / scale


Row = Tuple[str, float, str]          # (name, us_per_call, derived)


def timed(name: str, fn: Callable[[], str]) -> Row:
    t0 = time.monotonic()
    derived = fn()
    return (name, (time.monotonic() - t0) * 1e6, derived)


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


def write_bench_json(name: str, payload: dict, out_dir: str = ".") -> str:
    """Write a machine-readable benchmark artifact ``BENCH_<name>.json``
    (the contract downstream tooling / CI trend jobs consume); returns
    the path.  ``default=str`` keeps numpy scalars and labels writable."""
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    return path
