"""Device-resident preprocessing: fused decode+augment + HBM tier vs
the host pipeline.

The device route (ISSUE-7) removes the host from the steady-state data
path twice over: cold samples run decode and augment fused in one
Pallas launch fed by per-sample scalars (no decoded image, no payload
upload), and warm samples are served straight out of the device-side
HBM cache tier with zero host→device bytes.  This benchmark measures
both claims on the *live* stack:

* ``pallas-augment`` — the strongest host configuration from
  fig_pipeline_throughput: stage-parallel executor, host decode,
  Pallas-batched augment, DRAM cache;
* ``fused-device`` — the device executor with the *same* host DRAM
  budget plus a device cache tier sized for the augmented working set.

The two modes share the sampler, the admission/eviction policies
(``capacity``/``lru`` — the single-job benchmark must let augmented
rows persist across epochs; the paper's multi-job unseen-only/refcount
reuse semantics are exercised by the workload suite), the storage
token bucket, and the host DRAM bytes.  The device mode's only edge is
the HBM tier — which is precisely the feature under test: Seneca's
pitch is that idle accelerator memory is cache capacity the host
pipeline structurally does not have, and the constrained-storage
regime below (DRAM too small for the working set) is the regime the
paper targets.

Both modes warm one full epoch (jit traces + cache fill) and then
report the median samples/s of three steady-state timed windows.  A
separate small all-resident configuration runs two epochs and records
the ``"h2d"`` telemetry channel around epoch 2 — the zero-copy claim
is an exact byte count, not a rate.

Emits ``BENCH_device.json``; ``--check`` asserts fused-device beats
the pallas-augment baseline AND that the all-HBM-hit epoch moved zero
h2d payload bytes (the CI smoke gate).
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List, Tuple

from benchmarks.common import write_bench_json
from repro.api import SenecaServer
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny


def run_mode(label: str, *, n_samples: int, batch: int, windows: int,
             window_batches: int, bandwidth: float, n_workers: int,
             cache_frac: float, seed: int = 0) -> Dict:
    ds = tiny(n=n_samples)
    budget = int(cache_frac * n_samples * ds.augmented_bytes())
    common = dict(cache_bytes=budget, seed=seed, use_ods=False,
                  admission="capacity", eviction="lru")
    if label == "fused-device":
        hbm = int(1.2 * n_samples * ds.augmented_bytes())
        server = SenecaServer.for_dataset(
            ds, device_cache_bytes=hbm, hbm_split=(0.0, 0.0, 1.0),
            **common)
        pipe_kw = dict(executor="device")
    else:
        server = SenecaServer.for_dataset(
            ds, augment_backend="pallas", **common)
        pipe_kw = dict(executor="stage-parallel", prefetch=2)
    storage = RemoteStorage(ds, bandwidth=bandwidth)
    pipe = DSIPipeline(server.open_session(batch_size=batch), storage,
                       n_workers=n_workers, seed=seed, **pipe_kw)
    for _ in range(n_samples // batch):   # one warm epoch: traces + fill
        pipe.next_batch()
    rates = []
    for _ in range(windows):
        t0 = time.monotonic()
        for _ in range(window_batches):
            pipe.next_batch()
        rates.append(window_batches * batch / (time.monotonic() - t0))
    stats = server.stats()
    result = {
        "mode": label,
        "samples_per_s": statistics.median(rates),
        "window_samples_per_s": [round(r, 1) for r in rates],
        "stage_times_s": pipe.times.as_dict(),
        "cache_hit_rate": stats["cache_lookup_hit_rate"],
        "h2d_bytes": server.service.telemetry.channel_total_bytes("h2d"),
        "storage_fetches": storage.fetches,
    }
    if "residency_counts" in stats:
        result["residency_counts"] = stats["residency_counts"]
    if "hbm" in stats:
        result["hbm_hits"] = sum(s["hbm_hits"] for s in stats["hbm"].values())
        result["hbm_bytes_used"] = stats["hbm_bytes_used"]
    pipe.stop()
    server.close()
    return result


def run_zero_h2d_epoch(*, n_samples: int, batch: int, seed: int = 0) -> Dict:
    """Two epochs with an HBM tier sized for the whole augmented set:
    epoch 2 must serve every sample device-resident with zero bytes on
    the h2d channel."""
    ds = tiny(n=n_samples)
    hbm = int(1.2 * n_samples * ds.augmented_bytes())
    server = SenecaServer.for_dataset(
        ds, cache_frac=0.25, seed=seed, use_ods=False,
        admission="capacity", eviction="lru",
        device_cache_bytes=hbm, hbm_split=(0.0, 0.0, 1.0))
    pipe = DSIPipeline(server.open_session(batch_size=batch),
                       RemoteStorage(ds), n_workers=2, executor="device",
                       seed=seed)
    tel = server.service.telemetry
    for _ in range(n_samples // batch):           # epoch 1: fill HBM
        pipe.next_batch()
    before = tel.channel_total_bytes("h2d")
    for _ in range(n_samples // batch):           # epoch 2: all HBM hits
        pipe.next_batch()
    stats = server.stats()
    result = {
        "epoch1_h2d_bytes": before,
        "epoch2_h2d_bytes": tel.channel_total_bytes("h2d") - before,
        "residency_counts": stats["residency_counts"],
        "hbm_hits": sum(s["hbm_hits"] for s in stats["hbm"].values()),
    }
    pipe.stop()
    server.close()
    return result


def run(full: bool = False, check: bool = False) -> List[Tuple[str, str]]:
    knobs = dict(n_samples=4_096 if full else 1_024, batch=16,
                 windows=3, window_batches=16 if full else 8,
                 bandwidth=8e6, n_workers=4, cache_frac=0.15)
    results = {label: run_mode(label, **knobs)
               for label in ("pallas-augment", "fused-device")}

    def sps(label):
        return results[label]["samples_per_s"]

    if check and sps("fused-device") <= sps("pallas-augment"):
        # one retry before declaring a regression (same rationale as
        # fig_pipeline_throughput: one noisy CI window can sink a
        # 3-window median); the artifact is built from the retried
        # numbers so the JSON never contradicts a passing gate
        for label in ("pallas-augment", "fused-device"):
            results[label] = run_mode(label, **knobs)

    zero = run_zero_h2d_epoch(n_samples=512 if full else 128, batch=16)
    payload = {"config": {k: str(v) for k, v in knobs.items()},
               "zero_h2d_epoch": zero, **results}
    path = write_bench_json("device", payload)

    base, dev = sps("pallas-augment"), sps("fused-device")
    rows = [(f"fig_device/{label}",
             f"sps={r['samples_per_s']:.0f} x{r['samples_per_s'] / base:.2f} "
             f"h2d={r['h2d_bytes']} windows={r['window_samples_per_s']}")
            for label, r in results.items()]
    rows.append(("fig_device/zero_h2d_epoch",
                 f"epoch2_h2d={zero['epoch2_h2d_bytes']} "
                 f"hbm_hits={zero['hbm_hits']} "
                 f"hbm_resident={zero['residency_counts'].get('hbm', 0)}"))
    rows.append(("fig_device/summary",
                 f"fused-device speedup x{dev / base:.2f} json={path}"))
    if check:
        assert dev > base, (
            f"fused-device ({dev:.0f} sps) must beat the pallas-augment "
            f"baseline ({base:.0f} sps)")
        assert zero["epoch2_h2d_bytes"] == 0, (
            f"all-HBM-hit epoch shipped {zero['epoch2_h2d_bytes']} h2d "
            f"bytes (expected 0)")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="assert fused-device beats pallas-augment and "
                         "the HBM-hit epoch is zero-h2d (CI)")
    args = ap.parse_args()
    for name, derived in run(full=args.full, check=args.check):
        print(f"{name},{derived}")
