"""Serving steps: batched prefill + decode with KV caches.

``Server`` implements simple continuous batching over a fixed slot count:
requests occupy slots, prefill fills the slot's cache region, decode steps
advance all active slots in lockstep (one jitted decode_step per token).

Requests carry arrival/admit/finish timestamps (stamped by the server
through a pluggable ``now`` time source, so an open-loop driver can pass
the same clock its arrival schedule runs on) — per-request end-to-end
latency is ``done_s - arrival_s``, queue wait is ``admitted_s -
arrival_s``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new: int = 16
    arrival_s: float = 0.0       # caller-stamped (open-loop drivers)
    # runtime
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    admitted_s: float = 0.0      # server-stamped at slot admission
    done_s: float = 0.0          # server-stamped when max_new reached

    @property
    def latency_s(self) -> float:
        """End-to-end arrival→finish latency (0 until done)."""
        return self.done_s - self.arrival_s if self.done else 0.0


class Server:
    """Batched decode over ``n_slots`` sequences with a shared jitted step."""

    def __init__(self, model: Model, params, n_slots: int, s_max: int,
                 now: Optional[Callable[[], float]] = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.s_max = s_max
        self.cache = model.init_cache(batch=n_slots, s_max=s_max)
        self.pos = np.zeros(n_slots, np.int64)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self._decode = jax.jit(model.decode_step)
        self._now = now or time.monotonic
        self.steps = 0

    def add_request(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                self.pos[i] = 0
                req.admitted_s = self._now()
                # sequential prefill through the decode path keeps one
                # compiled program; bulk prefill is model.prefill
                for t in req.prompt:
                    self._step_slot(i, int(t))
                return True
        return False

    def _step_slot(self, slot: int, token: int) -> int:
        tokens = np.zeros((self.n_slots, 1), np.int32)
        tokens[slot, 0] = token
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.int32(self.pos[slot]))
        self.pos[slot] += 1
        self.steps += 1
        return int(jnp.argmax(logits[slot, 0, :self.model.cfg.vocab_size]))

    def decode_round(self) -> int:
        """One lockstep decode for all active slots; returns #active."""
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and not s.done]
        if not active:
            return 0
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            req = self.slots[i]
            tokens[i, 0] = req.generated[-1] if req.generated else \
                int(req.prompt[-1])
        # all slots share one position index in this simple scheduler:
        # use per-slot max; decode_step takes a scalar index so we step the
        # furthest slot's position (slots are prefilling in lockstep in the
        # examples; ragged positions are future work).
        idx = int(self.pos[active].max())
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.int32(idx))
        for i in active:
            req = self.slots[i]
            nxt = int(jnp.argmax(logits[i, 0, :self.model.cfg.vocab_size]))
            req.generated.append(nxt)
            self.pos[i] = idx + 1
            if len(req.generated) >= req.max_new:
                req.done = True    # caller harvests and frees the slot
                req.done_s = self._now()
        self.steps += 1
        return len(active)
