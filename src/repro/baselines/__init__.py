"""Baseline dataloaders (Table 7), expressed as simulator LoaderSpecs.

Each baseline from the paper's comparison matrix is a configuration of the
same mechanistic substrate (sim/desim.py) rather than a fork — PyTorch and
DALI ride the page-cache LRU, MINIO pins encoded samples without eviction,
Quiver over-samples 10x and substitutes, SHADE importance-samples on one
thread, MDP partitions without ODS.  The live (threaded) pipeline runs the
Seneca and naive policies; simulator-only baselines model the rest.
"""
from repro.sim.desim import (ALL_LOADERS, DALI_CPU, DALI_GPU, MDP_ONLY,
                             MINIO, PYTORCH, QUIVER, SENECA, SHADE,
                             LoaderSpec)

__all__ = ["ALL_LOADERS", "DALI_CPU", "DALI_GPU", "MDP_ONLY", "MINIO",
           "PYTORCH", "QUIVER", "SENECA", "SHADE", "LoaderSpec"]
