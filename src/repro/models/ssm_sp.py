"""Sequence-parallel SSD (beyond-paper, §Perf zamba2/mamba2 iteration).

Prefill at 32k with TP pays a residual-stream all-reduce per mamba layer
(~0.5 GB each).  This layout shards the *sequence* over 'model' instead and
keeps weights replicated; the only cross-rank traffic per layer is

* a conv halo — the previous rank's last (d_conv-1) pre-conv rows;
* the SSD state hand-off — per-rank summaries (final state with h0=0 and the
  rank's total log-decay) are all-gathered (~4 MB) and every rank computes
  its incoming state as the exclusive affine scan over rank summaries:

      h0_r = sum_{j<r} S_j * exp( cum[r-1] - cum[j] ),   cum = cumsum(logD)

The SSD core runs twice (once for summaries with h0=0, once with the true
h0); the intra-chunk quadratic work is a small fraction of the block's
projection FLOPs, so the second pass costs ~15% compute for a ~10x drop in
wire bytes.  Validated against the single-device ssm_block in
tests/test_distributed.py::test_seq_parallel_ssd_matches_local.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.compat import pvary, shard_map
from repro.models.ssm import _ssd_core

F32 = jnp.float32


def ssm_block_seq_parallel(p: Dict, x: jax.Array, cfg: ModelConfig,
                           mesh, *, axis: str = "model",
                           batch_axes=("data",)) -> jax.Array:
    """Mamba2 block with the sequence sharded over ``axis``.

    x: (B, S, D), S divisible by mesh.shape[axis]; weights replicated.
    """
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    n = mesh.shape[axis]
    K = s.d_conv

    def local(x_l, wz, wx, wB, wC, wdt, dt_bias, A_log, D_skip,
              conv_x, conv_B, conv_C, norm_w, wo):
        B, S_loc, _ = x_l.shape
        z = jnp.einsum("bsd,di->bsi", x_l, wz)
        xs = jnp.einsum("bsd,di->bsi", x_l, wx)
        Bm = jnp.einsum("bsd,dn->bsn", x_l, wB)
        Cm = jnp.einsum("bsd,dn->bsn", x_l, wC)
        dt = jnp.einsum("bsd,dh->bsh", x_l, wdt)

        # ---- causal conv with halo from the previous rank ----
        cat = jnp.concatenate([xs, Bm, Cm], axis=-1)      # (B, S_loc, C)
        perm = [(i, i + 1) for i in range(n - 1)]
        halo = jax.lax.ppermute(cat[:, -(K - 1):, :], axis, perm)
        full = jnp.concatenate([halo, cat], axis=1)       # (B,S_loc+K-1,C)
        wfull = jnp.concatenate([conv_x, conv_B, conv_C], axis=-1)  # (K, C)
        conv = jnp.zeros(cat.shape, F32)
        for k in range(K):
            conv = conv + full[:, k:k + S_loc, :].astype(F32) \
                * wfull[k].astype(F32)
        conv = jax.nn.silu(conv).astype(x_l.dtype)
        xs = conv[..., :d_in]
        Bm = conv[..., d_in:d_in + s.d_state]
        Cm = conv[..., d_in + s.d_state:]

        dt = jax.nn.softplus(dt.astype(F32) + dt_bias.astype(F32))
        A = -jnp.exp(A_log.astype(F32))
        xh = xs.reshape(B, S_loc, nh, s.head_dim)

        # ---- pass 1: local summaries (h0 = 0) ----
        chunk = min(s.chunk, S_loc)
        vary = tuple(batch_axes) + (axis,)
        z0 = pvary(
            jnp.zeros((B, nh, s.head_dim, s.d_state), F32), vary)
        _, S_r = _ssd_core(xh, dt, A, Bm, Cm, chunk, h0=z0)
        logD_r = jnp.sum(dt * A, axis=1)                  # (B, nh)

        # ---- exclusive affine scan across ranks ----
        Ss = jax.lax.all_gather(S_r, axis)                # (n, B, nh, P, N)
        Ls = jax.lax.all_gather(logD_r, axis)             # (n, B, nh)
        r = jax.lax.axis_index(axis)
        cum = jnp.cumsum(Ls, axis=0)
        cum_prev = cum[r] - Ls[r]                         # cum[r-1]
        w = jnp.exp(cum_prev[None] - cum)                 # (n, B, nh)
        mask = (jnp.arange(n) < r)[:, None, None]
        w = jnp.where(mask, w, 0.0)
        h0 = jnp.einsum("nbh,nbhpq->bhpq", w, Ss)

        # ---- pass 2: true state ----
        y, _ = _ssd_core(xh, dt, A, Bm, Cm, chunk, h0=h0)
        y = y + xh.astype(F32).astype(y.dtype) \
            * D_skip.astype(y.dtype)[None, None, :, None]
        y = y.reshape(B, S_loc, d_in)
        y = y * jax.nn.silu(z.astype(F32)).astype(y.dtype)
        yf = y.astype(F32)
        y = (yf * jax.lax.rsqrt(
            jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
            * norm_w.astype(F32)).astype(x_l.dtype)
        return jnp.einsum("bsi,id->bsd", y, wo)

    weights = (p["wz"], p["wx"], p["wB"], p["wC"], p["wdt"], p["dt_bias"],
               p["A_log"], p["D_skip"], p["conv_x"], p["conv_B"],
               p["conv_C"], p["norm"], p["wo"])
    x_spec = P(batch_axes, axis, None)
    f = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec,) + (P(),) * len(weights),
        out_specs=x_spec)
    return f(x, *weights)
