"""Mixture-of-Experts FFN with expert parallelism.

Design (see DESIGN.md §3):

* **Routing** — top-k softmax gating with capacity-based token dropping.
* **Dispatch** — sort-based: token/expert assignments are sorted by expert id
  and scattered into a dense ``(E_local, C, D)`` buffer.  No ``(T, E, C)``
  one-hot einsum is ever materialized (that classic "dropping" formulation
  costs ~40% extra FLOPs at 384 experts; the sorted form keeps the FLOP count
  equal to the useful expert GEMMs).
* **Expert parallelism** — the layer runs under ``shard_map``: activations
  arrive batch-sharded over the data axes and replicated over ``model``;
  expert weights are sharded over ``model``.  Each model-rank dispatches only
  to its local experts and the partial outputs are combined with a single
  ``psum`` over ``model``.  Router compute is replicated across model ranks
  (it is ~E·D flops/token — noise next to the expert GEMMs).
* **Shared experts** — fused into one dense gated MLP of width
  ``n_shared * d_ff_expert`` (TP-sharded like a regular MLP).

Without a mesh (smoke tests) the same sort-based dispatch runs locally over
all experts.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.compat import shard_map
from repro.distributed.sharding import current_rules, shard
from repro.models.params import ParamDef

F32 = jnp.float32


def moe_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    e = cfg.moe
    defs = {
        "router": ParamDef((d, e.n_experts), ("embed", "expert"), scale=0.1),
        "we_gate": ParamDef((e.n_experts, d, e.d_ff_expert),
                            ("expert", "embed", None)),
        "we_up": ParamDef((e.n_experts, d, e.d_ff_expert),
                          ("expert", "embed", None)),
        "we_out": ParamDef((e.n_experts, e.d_ff_expert, d),
                           ("expert", None, "embed"),
                           scale=1.0 / max(1, (2 * cfg.n_layers)) ** 0.5),
    }
    if e.n_shared:
        f = e.n_shared * e.d_ff_expert
        defs["ws_gate"] = ParamDef((d, f), ("embed", "mlp"))
        defs["ws_up"] = ParamDef((d, f), ("embed", "mlp"))
        defs["ws_out"] = ParamDef((f, d), ("mlp", "embed"),
                                  scale=1.0 / max(1, (2 * cfg.n_layers)) ** 0.5)
    return defs


# ---------------------------------------------------------------------------
# Local (per-shard) sorted dispatch + expert GEMMs
# ---------------------------------------------------------------------------

def _dispatch_local(x2d: jax.Array, top_e: jax.Array, top_g: jax.Array,
                    e_start: int, n_local: int, capacity: int,
                    we_gate, we_up, we_out) -> jax.Array:
    """Sorted capacity dispatch over experts [e_start, e_start+n_local).

    x2d: (T, D);  top_e/top_g: (T, k) expert ids / gate weights.
    Returns partial output (T, D) — contributions of local experts only.
    """
    T, D = x2d.shape
    k = top_e.shape[1]
    flat_e = top_e.reshape(-1)                       # (T*k,)
    flat_g = top_g.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)

    local = (flat_e >= e_start) & (flat_e < e_start + n_local)
    # sort by (is_remote, expert): local assignments first, grouped by expert
    sort_key = jnp.where(local, flat_e - e_start, n_local)
    order = jnp.argsort(sort_key, stable=True)
    s_e = sort_key[order]                            # sorted local-expert ids
    s_tok = flat_tok[order]
    s_g = flat_g[order]

    # position within expert (for capacity slotting): running count per expert
    ones = jnp.ones_like(s_e)
    seg_pos = jnp.cumsum(ones) - 1
    # index of first occurrence of each expert id in the sorted list
    first_idx = jnp.searchsorted(s_e, jnp.arange(n_local + 1), side="left")
    pos_in_e = seg_pos - first_idx[jnp.clip(s_e, 0, n_local)]

    keep = (s_e < n_local) & (pos_in_e < capacity)
    slot = jnp.where(keep, s_e * capacity + pos_in_e, n_local * capacity)

    # gather tokens into (E_local*C, D) buffer (one overflow row, dropped)
    buf = jnp.zeros((n_local * capacity + 1, D), x2d.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], x2d[s_tok], 0))
    buf = buf[:-1].reshape(n_local, capacity, D)

    # expert GEMMs (batched over local experts)
    g = jnp.einsum("ecd,edf->ecf", buf, we_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, we_up)
    h = jax.nn.silu(g.astype(F32)).astype(x2d.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, we_out)        # (E_local, C, D)

    # combine: gather back to assignments, weight by gate, sum into tokens
    y_flat = y.reshape(n_local * capacity, D)
    y_tok = jnp.where(keep[:, None],
                      y_flat[jnp.clip(slot, 0, n_local * capacity - 1)], 0)
    y_tok = y_tok * s_g[:, None].astype(y_tok.dtype)
    out = jnp.zeros_like(x2d).at[s_tok].add(y_tok)
    return out


def _route(x2d: jax.Array, router_w: jax.Array, k: int):
    logits = jnp.einsum("td,de->te", x2d, router_w).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(probs, k)
    top_g = top_g / jnp.clip(jnp.sum(top_g, axis=-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    E = router_w.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=F32), axis=1), axis=0) / k
    aux = E * jnp.sum(me * ce)
    return top_e, top_g.astype(x2d.dtype), aux


def _capacity(T: int, k: int, E: int, factor: float) -> int:
    cap = int(T * k * factor / E) + 1
    return max(cap, 4)


# ---------------------------------------------------------------------------
# Public layer
# ---------------------------------------------------------------------------

def moe_ffn(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. x: (B, S, D). Returns (y, aux_loss)."""
    e = cfg.moe
    B, S, D = x.shape
    rules = current_rules()

    shared_y = 0.0
    if "ws_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["ws_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["ws_up"])
        h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
        h = shard(h, "batch", "act_seq", "act_mlp")
        shared_y = jnp.einsum("bsf,fd->bsd", h, p["ws_out"])

    use_ep = (rules.enabled and rules.mesh is not None
              and rules.ep_axis is not None)
    if use_ep:
        mesh = rules.mesh
        ep_axis = rules.ep_axis
        ep_size = mesh.shape[ep_axis]
        n_local = e.n_experts // ep_size
        batch_spec = rules.batch_axes
        if batch_spec is None:
            reduce_axes: tuple = ()
        elif isinstance(batch_spec, tuple):
            reduce_axes = batch_spec
        else:
            reduce_axes = (batch_spec,)

        def body(x_l, router_w, we_gate, we_up, we_out):
            Bl, Sl, Dl = x_l.shape
            x2d = x_l.reshape(Bl * Sl, Dl)
            top_e, top_g, aux = _route(x2d, router_w, e.top_k)
            cap = _capacity(Bl * Sl, e.top_k, e.n_experts, e.capacity_factor)
            r = jax.lax.axis_index(ep_axis)
            part = _dispatch_local(
                x2d, top_e, top_g, r * n_local, n_local, cap,
                we_gate, we_up, we_out)
            out = jax.lax.psum(part, ep_axis)
            if reduce_axes:
                aux = jax.lax.pmean(aux, reduce_axes)
            return out.reshape(Bl, Sl, Dl), aux

        y, aux = shard_map(
            body, mesh=mesh,
            in_specs=(P(batch_spec, None, None), P(None, None),
                      P(ep_axis, None, None), P(ep_axis, None, None),
                      P(ep_axis, None, None)),
            out_specs=(P(batch_spec, None, None), P()),
        )(x, p["router"], p["we_gate"], p["we_up"], p["we_out"])
    else:
        x2d = x.reshape(B * S, D)
        top_e, top_g, aux = _route(x2d, p["router"], e.top_k)
        cap = _capacity(B * S, e.top_k, e.n_experts, e.capacity_factor)
        y = _dispatch_local(x2d, top_e, top_g, 0, e.n_experts, cap,
                            p["we_gate"], p["we_up"], p["we_out"])
        y = y.reshape(B, S, D)

    y = y + shared_y
    return shard(y, "batch", "act_seq", "act_embed"), aux * e.aux_loss_weight
