"""Transformer building blocks — pure functions over ParamDef-declared params.

Conventions:
* activations bf16, reductions/norm/softmax accumulate fp32;
* attention layout (B, S, H, hd); GQA groups q-heads over kv-heads;
* logical sharding via :func:`repro.distributed.sharding.shard`;
* every block has both a full-sequence form and a single-token decode form.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.params import ParamDef

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_def(d: int) -> ParamDef:
    return ParamDef((d,), ("embed",), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))
    if positions.ndim == 1:
        ang = positions.astype(F32)[:, None] * freqs[None, :]        # (S, half)
        ang = ang[None, :, None, :]                                   # (1,S,1,half)
    else:
        ang = positions.astype(F32)[..., None] * freqs                # (B,S,half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig, *, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    defs = {
        "wq": ParamDef((d, H * hd), ("embed", "q_heads")),
        "wk": ParamDef((d, K * hd), ("embed", "kv_heads")),
        "wv": ParamDef((d, K * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, d), ("q_heads", "embed"),
                       scale=1.0 / max(1, (2 * cfg.n_layers)) ** 0.5),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef((H * hd,), ("q_heads",), init="zeros")
        defs["bk"] = ParamDef((K * hd,), ("kv_heads",), init="zeros")
        defs["bv"] = ParamDef((K * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), init="ones")
        defs["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return defs


def _project_qkv(p: Dict, x: jax.Array, kv_x: jax.Array, cfg: ModelConfig,
                 positions, kv_positions, *, use_rope: bool = True):
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", kv_x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", kv_x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, -1, H, hd)
    k = k.reshape(B, -1, K, hd)
    v = v.reshape(B, -1, K, hd)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, kv_positions, cfg.rope_theta)
    q = shard(q, "batch", "act_seq", "act_heads", None)
    k = shard(k, "batch", "act_seq", "act_kv", None)
    v = shard(v, "batch", "act_seq", "act_kv", None)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """Grouped scaled-dot-product attention. q:(B,Sq,H,hd) k/v:(B,Sk,K,hd).

    Materializes (Sq, Sk) scores — use only when Sq*Sk is small (decode,
    short sequences).  Long sequences go through :func:`blockwise_attention`.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K if K else 1
    q = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(F32) / (hd ** 0.5)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_offset: int = 0, q_block: int = 512):
    """Flash-style attention expressed in XLA: lax.scan over query blocks.

    Never materializes more than one (B, K, G, q_block, Sk) score tile, so
    32k prefill compiles within HBM.  Online softmax is unnecessary because
    each scan step owns its complete score row.
    q: (B,Sq,H,hd); k/v: (B,Sk,K,hd); q_offset = absolute position of q[0].
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qb = min(q_block, Sq)
    nb = Sq // qb
    assert Sq % qb == 0, (Sq, qb)
    qr = q.reshape(B, nb, qb, K, G, hd)
    qr = jnp.moveaxis(qr, 1, 0)                       # (nb, B, qb, K, G, hd)
    kpos = jnp.arange(Sk)[None, :]

    def step(_, qi_and_idx):
        qi, bidx = qi_and_idx
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qi, k).astype(F32)
        scores = scores / (hd ** 0.5)
        qpos = q_offset + bidx * qb + jnp.arange(qb)[:, None]
        m = jnp.ones((qb, Sk), bool)
        if causal:
            m &= kpos <= qpos
        if window:
            m &= kpos > qpos - window
        scores = jnp.where(m[None, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(qi.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
        return None, out

    _, outs = jax.lax.scan(step, None, (qr, jnp.arange(nb)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)
    return out


def causal_mask(Sq: int, Sk: int, *, window: int = 0,
                offset: int = 0) -> jax.Array:
    """(1,1,1,Sq,Sk) bool; offset = absolute position of query 0."""
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None, None, :, :]


# score tiles above this element count switch to blockwise attention
_DIRECT_SDPA_LIMIT = 1 << 21


def attention(p: Dict, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, causal: bool, window: int = 0,
              kv_x: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None,
              use_rope: bool = True, return_kv: bool = False):
    """Full-sequence attention (training / prefill / cross)."""
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, x, kv_x, cfg, positions, kv_positions,
                           use_rope=use_rope)
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq * Sk <= _DIRECT_SDPA_LIMIT:
        mask = causal_mask(Sq, Sk, window=window) if causal else None
        out = _sdpa(q, k, v, mask, cfg)
    else:
        out = blockwise_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(x.shape[0], -1, cfg.n_heads * cfg.resolved_head_dim)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    y = shard(y, "batch", "act_seq", "act_embed")
    if return_kv:
        return y, k, v
    return y


def attention_decode(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                     cache_k: jax.Array, cache_v: jax.Array,
                     index: jax.Array, window: int = 0,
                     use_rope: bool = True):
    """One-token decode against a preallocated KV cache.

    x: (B, 1, D); cache_k/v: (B, S_max, K, hd); index: scalar position.
    Returns (y, new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = jnp.full((1,), index, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, x, cfg, pos, pos, use_rope=use_rope)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, index, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, index, axis=1)
    cache_k = shard(cache_k, "batch", "kv_seq", "act_kv", None)
    cache_v = shard(cache_v, "batch", "kv_seq", "act_kv", None)
    S_max = cache_k.shape[1]
    kpos = jnp.arange(S_max)
    valid = kpos <= index
    if window:
        valid &= kpos > index - window
    mask = valid[None, None, None, None, :]
    out = _sdpa(q, cache_k, cache_v, mask, cfg)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return shard(y, "batch", None, "act_embed"), cache_k, cache_v


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi_gate": ParamDef((d, f), ("embed", "mlp")),
        "wi_up": ParamDef((d, f), ("embed", "mlp")),
        "wo": ParamDef((f, d), ("mlp", "embed"),
                       scale=1.0 / max(1, (2 * cfg.n_layers)) ** 0.5),
    }


def mlp(p: Dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    h = shard(h, "batch", "act_seq", "act_mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    return shard(y, "batch", "act_seq", "act_embed")


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig, v_pad: int) -> Dict:
    d = cfg.d_model
    defs = {"tok": ParamDef((v_pad, d), ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((d, v_pad), ("embed", "vocab"))
    return defs


def embed(p: Dict, tokens: jax.Array) -> jax.Array:
    y = p["tok"][tokens]
    return shard(y, "batch", "act_seq", "act_embed")


def logits(p: Dict, x: jax.Array) -> jax.Array:
    w = p["head"] if "head" in p else p["tok"].T
    out = jnp.einsum("bsd,dv->bsv", x, w)
    return shard(out, "batch", "act_seq", "act_vocab")
