"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD algorithm, jnp reference implementation (the Pallas kernel in
``kernels/ssd_scan`` accelerates the same computation on TPU; both share this
module's parameterization).

Layout: d_inner = expand * d_model, nh = d_inner / head_dim SSD heads,
ngroups = 1 (B, C shared across heads).  TP shards heads (``ssm_inner``)
over 'model'; B/C/dt projections are tiny and replicated.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.params import ParamDef

F32 = jnp.float32


def ssm_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    nh = d_in // s.head_dim
    return {
        "wz": ParamDef((d, d_in), ("embed", "ssm_inner")),
        "wx": ParamDef((d, d_in), ("embed", "ssm_inner")),
        "wB": ParamDef((d, s.d_state), ("embed", "ssm_state")),
        "wC": ParamDef((d, s.d_state), ("embed", "ssm_state")),
        "wdt": ParamDef((d, nh), ("embed", "ssm_inner")),
        "dt_bias": ParamDef((nh,), ("ssm_inner",), init="zeros"),
        "A_log": ParamDef((nh,), ("ssm_inner",), init="zeros"),
        "D_skip": ParamDef((nh,), ("ssm_inner",), init="ones"),
        "conv_x": ParamDef((s.d_conv, d_in), ("conv", "ssm_inner"), scale=0.5),
        "conv_B": ParamDef((s.d_conv, s.d_state), ("conv", "ssm_state"),
                           scale=0.5),
        "conv_C": ParamDef((s.d_conv, s.d_state), ("conv", "ssm_state"),
                           scale=0.5),
        "norm": ParamDef((d_in,), ("ssm_inner",), init="ones"),
        "wo": ParamDef((d_in, d), ("ssm_inner", "embed"),
                       scale=1.0 / max(1, (2 * cfg.n_layers)) ** 0.5),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=F32)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :].astype(F32) * w[i].astype(F32)
    return jax.nn.silu(out).astype(x.dtype)


def _ssd_chunked(xh, dt, A, Bmat, Cmat, chunk: int, h0=None,
                 head_group: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan, optionally lax.map'd over head groups.

    ``head_group > 0`` bounds the peak (B,nc,c,c,hg) decay tensor on a single
    host (smoke tests); under TP the per-device head count is already small
    and grouping would fight the 'model'-axis sharding, so it stays off.
    """
    nh = xh.shape[2]
    if head_group and nh > head_group and nh % head_group == 0:
        G = nh // head_group
        Bsz, S, _, Pd = xh.shape
        if h0 is None:
            h0 = jnp.zeros((Bsz, nh, Pd, Bmat.shape[-1]), F32)
        xg = jnp.moveaxis(xh.reshape(Bsz, S, G, head_group, Pd), 2, 0)
        dtg = jnp.moveaxis(dt.reshape(Bsz, S, G, head_group), 2, 0)
        Ag = A.reshape(G, head_group)
        hg = jnp.moveaxis(
            h0.reshape(Bsz, G, head_group, Pd, h0.shape[-1]), 1, 0)

        def f(args):
            xi, di, ai, hi = args
            return _ssd_core(xi, di, ai, Bmat, Cmat, chunk, hi)

        ys, hs = jax.lax.map(f, (xg, dtg, Ag, hg))
        y = jnp.moveaxis(ys, 0, 2).reshape(Bsz, S, nh, Pd)
        h = jnp.moveaxis(hs, 0, 1).reshape(Bsz, nh, Pd, h0.shape[-1])
        return y, h
    return _ssd_core(xh, dt, A, Bmat, Cmat, chunk, h0)


def _ssd_core(xh, dt, A, Bmat, Cmat, chunk: int,
              h0=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: (B, S, nh, P); dt: (B, S, nh) (post-softplus); A: (nh,) negative;
    Bmat/Cmat: (B, S, N).  Returns (y (B,S,nh,P), final state (B,nh,P,N)).
    """
    Bsz, S, nh, Pd = xh.shape
    N = Bmat.shape[-1]
    nc = S // chunk
    xc = xh.reshape(Bsz, nc, chunk, nh, Pd)
    dtc = dt.reshape(Bsz, nc, chunk, nh).astype(F32)
    Bc = Bmat.reshape(Bsz, nc, chunk, N)
    Cc = Cmat.reshape(Bsz, nc, chunk, N)

    dA = dtc * A.astype(F32)                       # (B, nc, c, nh), negative
    cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumulative
    seg_sum = cum[:, :, -1, :]                     # (B, nc, nh)

    # ---- intra-chunk (dense, quadratic in chunk) ----
    # decay(i, j) = exp(cum_i - cum_j) for j <= i
    li = cum[:, :, :, None, :]                     # (B,nc,c,1,nh)
    lj = cum[:, :, None, :, :]                     # (B,nc,1,c,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    decay = jnp.where(mask, jnp.exp(li - lj), 0.0)  # (B,nc,c,c,nh)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(F32), Bc.astype(F32))
    w = cb[..., None] * decay                       # (B,nc,c,c,nh)
    xdt = xc.astype(F32) * dtc[..., None]           # (B,nc,c,nh,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xdt)

    # ---- chunk states ----
    # state_c = sum_j exp(seg_sum - cum_j) * dt_j * B_j (x) x_j
    sdecay = jnp.exp(seg_sum[:, :, None, :] - cum)  # (B,nc,c,nh)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                        Bc.astype(F32), sdecay * dtc, xc.astype(F32))

    # ---- inter-chunk recurrence over nc (sequential scan) ----
    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, Pd, N), F32)

    def step(h, inp):
        st, seg = inp                               # (B,nh,P,N), (B,nh)
        h_new = h * jnp.exp(seg)[:, :, None, None] + st
        return h_new, h

    states_t = jnp.moveaxis(states, 1, 0)           # (nc, B, nh, P, N)
    seg_t = jnp.moveaxis(seg_sum, 1, 0)             # (nc, B, nh)
    h_final, h_prev = jax.lax.scan(step, h0, (states_t, seg_t))
    h_prev = jnp.moveaxis(h_prev, 0, 1)             # (B, nc, nh, P, N)

    # ---- contribution of carried-in state to each position ----
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         Cc.astype(F32), h_prev, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, nh, Pd)
    return y.astype(xh.dtype), h_final


def ssm_block(p: Dict, x: jax.Array, cfg: ModelConfig,
              h0=None, conv_state=None, *, return_state: bool = False):
    """Full-sequence Mamba2 block. x: (B, S, D)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim

    z = jnp.einsum("bsd,di->bsi", x, p["wz"])
    xs = jnp.einsum("bsd,di->bsi", x, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    xs = shard(xs, "batch", "act_seq", "act_inner")
    z = shard(z, "batch", "act_seq", "act_inner")

    xs = _causal_conv(xs, p["conv_x"])
    Bm = _causal_conv(Bm, p["conv_B"])
    Cm = _causal_conv(Cm, p["conv_C"])

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))
    A = -jnp.exp(p["A_log"].astype(F32))

    xh = xs.reshape(*xs.shape[:2], nh, s.head_dim)
    from repro.distributed.sharding import current_rules
    hg = 0 if current_rules().enabled else 8
    y, h_final = _ssd_chunked(xh, dt, A, Bm, Cm, min(s.chunk, xs.shape[1]),
                              h0, head_group=hg)
    y = y + xh.astype(F32).astype(y.dtype) * p["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(*xs.shape[:2], d_in)
    y = y * jax.nn.silu(z.astype(F32)).astype(y.dtype)
    # gated RMSNorm (Mamba2 normalizes after gating)
    yf = y.astype(F32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(F32)).astype(x.dtype)
    rules = current_rules()
    if rules.enabled and rules.mapping.get("ssm_gather_out"):
        # comm strategy: gather the inner-sharded y (bytes/4 vs psum of the
        # projected output) and run the out-proj redundantly per rank
        y = shard(y, "batch", "act_seq", None)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    out = shard(out, "batch", "act_seq", "act_embed")
    if return_state:
        return out, h_final
    return out


def ssm_decode_step(p: Dict, x: jax.Array, cfg: ModelConfig,
                    h: jax.Array, conv_buf: jax.Array):
    """Single-token recurrent step.

    x: (B, 1, D); h: (B, nh, P, N) fp32 state;
    conv_buf: (B, d_conv-1, d_in + 2N) previous conv inputs.
    Returns (y (B,1,D), h_new, conv_buf_new).
    """
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    Bsz = x.shape[0]

    z = jnp.einsum("bsd,di->bsi", x, p["wz"])[:, 0]
    xs = jnp.einsum("bsd,di->bsi", x, p["wx"])[:, 0]
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])[:, 0]
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])[:, 0]
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])[:, 0]

    # rolling causal conv over the last d_conv inputs
    cat = jnp.concatenate([xs, Bm, Cm], axis=-1)          # (B, d_in+2N)
    hist = jnp.concatenate([conv_buf, cat[:, None, :]], axis=1)
    new_buf = hist[:, 1:, :]
    wfull = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    conv = jnp.einsum("bkc,kc->bc", hist.astype(F32), wfull.astype(F32))
    conv = jax.nn.silu(conv)
    xs = conv[:, :d_in].astype(x.dtype)
    Bm = conv[:, d_in:d_in + s.d_state].astype(x.dtype)
    Cm = conv[:, d_in + s.d_state:].astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))  # (B, nh)
    A = -jnp.exp(p["A_log"].astype(F32))
    xh = xs.reshape(Bsz, nh, s.head_dim).astype(F32)

    decay = jnp.exp(dt * A)                                # (B, nh)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(F32), xh)
    h_new = h * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(F32), h_new)
    y = y + xh * p["D_skip"].astype(F32)[None, :, None]
    y = y.reshape(Bsz, d_in)
    y = y * jax.nn.silu(z.astype(F32))
    y = (y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(F32))
    out = jnp.einsum("bi,id->bd", y.astype(x.dtype), p["wo"])[:, None, :]
    return shard(out, "batch", None, "act_embed"), h_new, new_buf
