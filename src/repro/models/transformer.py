"""Model assembly for every family in the pool.

Families: dense / moe / vlm (decoder-only LM), encdec (seamless), ssm
(mamba2), hybrid (zamba2), encoder (vit).  All stacks scan over stacked
per-layer params so the HLO (and 512-way SPMD compile time) stays small.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as lyr
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.params import ParamDef, padded_vocab, stack_defs

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------

def _block_defs(cfg: ModelConfig, *, cross: bool = False,
                ssm: bool = False) -> Dict:
    d = {"ln1": lyr.rmsnorm_def(cfg.d_model)}
    if ssm:
        d["ssm"] = ssm_mod.ssm_defs(cfg)
        return d
    d["attn"] = lyr.attention_defs(cfg)
    if cross:
        d["lnc"] = lyr.rmsnorm_def(cfg.d_model)
        d["cross"] = lyr.attention_defs(cfg, cross=True)
    d["ln2"] = lyr.rmsnorm_def(cfg.d_model)
    if cfg.moe is not None:
        d["moe"] = moe_mod.moe_defs(cfg)
    else:
        d["mlp"] = lyr.mlp_defs(cfg)
    return d


def param_defs(cfg: ModelConfig) -> Dict:
    v_pad = padded_vocab(cfg.vocab_size) if cfg.vocab_size else 0
    defs: Dict = {"final_norm": lyr.rmsnorm_def(cfg.d_model)}
    if cfg.family in ("dense", "moe", "vlm"):
        defs["embed"] = lyr.embed_defs(cfg, v_pad)
        defs["blocks"] = stack_defs(_block_defs(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        defs["embed"] = lyr.embed_defs(cfg, v_pad)
        defs["blocks"] = stack_defs(_block_defs(cfg, ssm=True), cfg.n_layers)
    elif cfg.family == "hybrid":
        defs["embed"] = lyr.embed_defs(cfg, v_pad)
        defs["blocks"] = stack_defs(_block_defs(cfg, ssm=True), cfg.n_layers)
        defs["shared"] = _block_defs(cfg)          # weight-tied attn block
    elif cfg.family in ("encdec", "audio"):
        defs["embed"] = lyr.embed_defs(cfg, v_pad)
        defs["enc_blocks"] = stack_defs(_block_defs(cfg),
                                        cfg.n_encoder_layers)
        defs["enc_norm"] = lyr.rmsnorm_def(cfg.d_model)
        defs["blocks"] = stack_defs(_block_defs(cfg, cross=True),
                                    cfg.n_layers)
    elif cfg.family == "encoder":
        defs["pos_embed"] = ParamDef((cfg.frontend_tokens, cfg.d_model),
                                     (None, "embed"), init="embed")
        defs["blocks"] = stack_defs(_block_defs(cfg), cfg.n_layers)
        defs["head"] = ParamDef((cfg.d_model, cfg.n_classes),
                                ("embed", "classes"))
    else:
        raise ValueError(cfg.family)
    return defs


# ---------------------------------------------------------------------------
# Stacks (full-sequence)
# ---------------------------------------------------------------------------

def _attn_block(lp: Dict, x: jax.Array, cfg: ModelConfig, positions,
                *, causal: bool, window: int = 0, enc_out=None,
                use_rope: bool = True, return_kv: bool = False):
    h = lyr.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    a = lyr.attention(lp["attn"], h, cfg, positions=positions, causal=causal,
                      window=window, use_rope=use_rope, return_kv=return_kv)
    if return_kv:
        a, k, v = a
    x = x + a
    if "cross" in lp:
        h = lyr.rmsnorm(x, lp["lnc"], cfg.norm_eps)
        c = lyr.attention(lp["cross"], h, cfg, positions=positions,
                          causal=False, kv_x=enc_out,
                          kv_positions=jnp.arange(enc_out.shape[1]),
                          use_rope=False)
        x = x + c
    h = lyr.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        f, aux = moe_mod.moe_ffn(lp["moe"], h, cfg)
    else:
        f, aux = lyr.mlp(lp["mlp"], h), jnp.zeros((), F32)
    x = shard(x + f, "batch", "act_seq", "act_embed")
    if return_kv:
        return x, aux, k, v
    return x, aux


def _ssm_block(lp: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = lyr.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    from repro.distributed.sharding import current_rules
    rules = current_rules()
    if (rules.enabled and rules.mesh is not None
            and rules.mapping.get("act_seq") == "model"
            and cfg.family == "ssm"):
        from repro.models.ssm_sp import ssm_block_seq_parallel
        y = ssm_block_seq_parallel(
            lp["ssm"], h, cfg, rules.mesh,
            batch_axes=rules.batch_axes or ("data",))
        return x + y
    return x + ssm_mod.ssm_block(lp["ssm"], h, cfg)


def _scan_blocks(blocks, x, body, remat: str):
    if remat != "none":
        body = jax.checkpoint(body)

    def wrapped(carry, lp):
        return body(carry, lp), None

    (x, aux), _ = jax.lax.scan(wrapped, (x, jnp.zeros((), F32)), blocks)
    return x, aux


def run_decoder(params, x, cfg: ModelConfig, positions, *,
                causal: bool = True, window: int = 0, enc_out=None,
                use_rope: bool = True, remat: str = "none"):
    """Run the main block stack. Returns (x, aux_loss)."""
    if cfg.family in ("ssm",):
        def body(carry, lp):
            h, aux = carry
            return (_ssm_block(lp, h, cfg), aux)
        return _scan_blocks(params["blocks"], x, body, remat)

    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        sites = cfg.n_layers // k if k else 0
        aux_total = jnp.zeros((), F32)

        def body(carry, lp):
            h, aux = carry
            return (_ssm_block(lp, h, cfg), aux)

        done = 0
        for s in range(sites):
            grp = jax.tree.map(lambda a: a[s * k:(s + 1) * k],
                               params["blocks"])
            x, _ = _scan_blocks(grp, x, body, remat)
            x, aux = _attn_block(params["shared"], x, cfg, positions,
                                 causal=True, window=cfg.attn_window)
            aux_total = aux_total + aux
            done += k
        if done < cfg.n_layers:
            grp = jax.tree.map(lambda a: a[done:], params["blocks"])
            x, _ = _scan_blocks(grp, x, body, remat)
        return x, aux_total

    def body(carry, lp):
        h, aux = carry
        h, a = _attn_block(lp, h, cfg, positions, causal=causal,
                           window=window, enc_out=enc_out,
                           use_rope=use_rope)
        return (h, aux + a)

    return _scan_blocks(params["blocks"], x, body, remat)


def run_encoder(params, src: jax.Array, cfg: ModelConfig,
                remat: str = "none"):
    """Bidirectional encoder over frame embeddings (encdec families)."""
    positions = jnp.arange(src.shape[1])

    def body(carry, lp):
        h, aux = carry
        h, a = _attn_block(lp, h, cfg, positions, causal=False)
        return (h, aux + a)

    x, aux = _scan_blocks(params["enc_blocks"], src, body, remat)
    return lyr.rmsnorm(x, params["enc_norm"], cfg.norm_eps), aux


# ---------------------------------------------------------------------------
# Forward passes (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch: Dict, *,
            remat: str = "none") -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, aux_loss).

    batch keys by family:
      dense/moe/ssm/hybrid: tokens (B,S)
      vlm:    tokens (B,S-P) + patch_embeds (B,P,D)
      encdec: src_embeds (B,S_src,D) + tokens (B,S)
      encoder: patch_embeds (B,T,D)  -> returns class logits (B,n_classes)
    """
    if cfg.family == "encoder":
        x = batch["patch_embeds"].astype(jnp.bfloat16) + params["pos_embed"]
        x = shard(x, "batch", "act_seq", "act_embed")
        positions = jnp.arange(x.shape[1])

        def body(carry, lp):
            h, aux = carry
            h, a = _attn_block(lp, h, cfg, positions, causal=False,
                               use_rope=False)
            return (h, aux + a)

        x, aux = _scan_blocks(params["blocks"], x, body, remat)
        x = lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dc->bc", x[:, 0], params["head"])
        return logits, aux

    enc_out = None
    if cfg.family in ("encdec", "audio"):
        enc_out, _ = run_encoder(params, batch["src_embeds"].astype(
            jnp.bfloat16), cfg, remat)

    x = lyr.embed(params["embed"], batch["tokens"])
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)
        pe = shard(pe, "batch", "act_seq", "act_embed")
        x = jnp.concatenate([pe, x], axis=1)
    positions = jnp.arange(x.shape[1])
    x, aux = run_decoder(params, x, cfg, positions, causal=True,
                         window=0, enc_out=enc_out, remat=remat)
    x = lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lyr.logits(params["embed"], x)
    return logits, aux


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Masked CE over a padded vocab. labels < 0 are ignored."""
    v_pad = logits.shape[-1]
    lf = logits.astype(F32)
    if vocab_size and v_pad > vocab_size:
        pad_mask = jnp.arange(v_pad) >= vocab_size
        lf = jnp.where(pad_mask, -1e30, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(
        lf, jnp.clip(labels, 0, v_pad - 1)[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    mask = (labels >= 0).astype(F32)
    return jnp.sum(nll * mask) / jnp.clip(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ModelConfig, batch: Dict, *,
            remat: str = "none") -> jax.Array:
    logits, aux = forward(params, cfg, batch, remat=remat)
    if cfg.family == "encoder":
        lbl = batch["labels"]
        ce = cross_entropy(logits[:, None, :], lbl[:, None], cfg.n_classes)
        return ce + aux
    return cross_entropy(logits, batch["labels"], cfg.vocab_size) + aux


# ---------------------------------------------------------------------------
# KV / state caches + decode
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, B: int, s_max: int) -> Dict:
    """Decode-state ParamDefs (init=zeros; reuses the ParamDef machinery
    so abstract shapes and PartitionSpecs come for free)."""
    hd = cfg.resolved_head_dim
    K = cfg.n_kv_heads
    L = cfg.n_layers
    bf16, f32 = jnp.bfloat16, jnp.float32
    kv_axes = ("layers", "batch", "kv_seq", "act_kv", None)

    if cfg.family in ("dense", "moe", "vlm"):
        return {
            "k": ParamDef((L, B, s_max, K, hd), kv_axes, "zeros", dtype=bf16),
            "v": ParamDef((L, B, s_max, K, hd), kv_axes, "zeros", dtype=bf16),
        }
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        return {
            "h": ParamDef((L, B, nh, s.head_dim, s.d_state),
                          ("layers", "batch", "act_inner", None, None),
                          "zeros", dtype=f32),
            "conv": ParamDef((L, B, s.d_conv - 1, d_in + 2 * s.d_state),
                             ("layers", "batch", None, None), "zeros",
                             dtype=bf16),
        }
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        sites = cfg.n_layers // cfg.hybrid_attn_every
        W = min(s_max, cfg.attn_window or s_max)
        return {
            "h": ParamDef((L, B, nh, s.head_dim, s.d_state),
                          ("layers", "batch", "act_inner", None, None),
                          "zeros", dtype=f32),
            "conv": ParamDef((L, B, s.d_conv - 1, d_in + 2 * s.d_state),
                             ("layers", "batch", None, None), "zeros",
                             dtype=bf16),
            "ak": ParamDef((sites, B, W, K, hd), kv_axes, "zeros", dtype=bf16),
            "av": ParamDef((sites, B, W, K, hd), kv_axes, "zeros", dtype=bf16),
        }
    if cfg.family in ("encdec", "audio"):
        s_src = encdec_src_len(s_max)
        return {
            "k": ParamDef((L, B, s_max, K, hd), kv_axes, "zeros", dtype=bf16),
            "v": ParamDef((L, B, s_max, K, hd), kv_axes, "zeros", dtype=bf16),
            "ck": ParamDef((L, B, s_src, K, hd), kv_axes, "zeros", dtype=bf16),
            "cv": ParamDef((L, B, s_src, K, hd), kv_axes, "zeros", dtype=bf16),
        }
    raise ValueError(f"no decode cache for family {cfg.family}")


def encdec_src_len(seq_len: int) -> int:
    """Audio frames entering the encoder (8x downsampled frontend)."""
    return max(seq_len // 8, 16)


def _decode_attn_block(lp, x, cfg, ck, cv, index, *, window=0,
                       cross_kv=None):
    h = lyr.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if window:
        a, ck, cv = _attention_decode_window(lp["attn"], h, cfg, ck, cv,
                                             index, window)
    else:
        a, ck, cv = lyr.attention_decode(lp["attn"], h, cfg, cache_k=ck,
                                         cache_v=cv, index=index)
    x = x + a
    if cross_kv is not None:
        hq = lyr.rmsnorm(x, lp["lnc"], cfg.norm_eps)
        x = x + _cross_attention_cached(lp["cross"], hq, cfg, *cross_kv)
    h = lyr.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        f, _ = moe_mod.moe_ffn(lp["moe"], h, cfg)
    else:
        f = lyr.mlp(lp["mlp"], h)
    return x + f, ck, cv


def _cross_attention_cached(p, x, cfg, ck, cv):
    """Decode-time cross attention against precomputed encoder KV."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    out = lyr._sdpa(q, ck, cv, None, cfg)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def _attention_decode_window(p, x, cfg, ck, cv, index, window):
    """Ring-buffer windowed decode: slot = index % W; positions derivable."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = jnp.full((1,), index, dtype=jnp.int32)
    q, k, v = lyr._project_qkv(p, x, x, cfg, pos, pos)
    W = ck.shape[1]
    slot = jnp.mod(index, W)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=1)
    j = jnp.arange(W)
    slot_pos = index - jnp.mod(index - j, W)     # absolute pos stored in slot
    mask = (slot_pos >= 0)[None, None, None, None, :]
    out = lyr._sdpa(q, ck, cv, mask, cfg)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return y, ck, cv


def decode_step(params, cfg: ModelConfig, cache: Dict, tokens: jax.Array,
                index: jax.Array) -> Tuple[jax.Array, Dict]:
    """One-token decode. tokens: (B,1) int32; index: scalar position.

    Returns (logits (B,1,V), new cache).
    """
    x = lyr.embed(params["embed"], tokens)

    if cfg.family in ("dense", "moe", "vlm", "encdec", "audio"):
        cross = cfg.family in ("encdec", "audio")

        def body(x, inp):
            if cross:
                lp, ck, cv, cck, ccv = inp
                x, ck, cv = _decode_attn_block(lp, x, cfg, ck, cv, index,
                                               cross_kv=(cck, ccv))
                return x, (ck, cv, cck, ccv)
            lp, ck, cv = inp
            x, ck, cv = _decode_attn_block(lp, x, cfg, ck, cv, index)
            return x, (ck, cv)

        xs = (params["blocks"], cache["k"], cache["v"])
        if cross:
            xs = xs + (cache["ck"], cache["cv"])
        x, outs = jax.lax.scan(body, x, xs)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = outs[0], outs[1]
        if cross:
            new_cache["ck"], new_cache["cv"] = outs[2], outs[3]

    elif cfg.family == "ssm":
        def body(x, inp):
            lp, h, conv = inp
            hh = lyr.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, h, conv = ssm_mod.ssm_decode_step(lp["ssm"], hh, cfg, h, conv)
            return x + y, (h, conv)

        x, (hs, convs) = jax.lax.scan(
            body, x, (params["blocks"], cache["h"], cache["conv"]))
        new_cache = {"h": hs, "conv": convs}

    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        sites = cfg.n_layers // k
        hs_out, conv_out, ak_out, av_out = [], [], [], []

        def body(x, inp):
            lp, h, conv = inp
            hh = lyr.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            y, h, conv = ssm_mod.ssm_decode_step(lp["ssm"], hh, cfg, h, conv)
            return x + y, (h, conv)

        done = 0
        for s in range(sites):
            sl = lambda a: a[s * k:(s + 1) * k]
            x, (hs, convs) = jax.lax.scan(
                body, x, (jax.tree.map(sl, params["blocks"]),
                          sl(cache["h"]), sl(cache["conv"])))
            hs_out.append(hs)
            conv_out.append(convs)
            x, ak, av = _decode_attn_block(
                params["shared"], x, cfg, cache["ak"][s], cache["av"][s],
                index, window=cache["ak"].shape[2])
            ak_out.append(ak)
            av_out.append(av)
            done += k
        if done < cfg.n_layers:
            sl = lambda a: a[done:]
            x, (hs, convs) = jax.lax.scan(
                body, x, (jax.tree.map(sl, params["blocks"]),
                          sl(cache["h"]), sl(cache["conv"])))
            hs_out.append(hs)
            conv_out.append(convs)
        new_cache = {
            "h": jnp.concatenate(hs_out, 0),
            "conv": jnp.concatenate(conv_out, 0),
            "ak": jnp.stack(ak_out, 0),
            "av": jnp.stack(av_out, 0),
        }
    else:
        raise ValueError(cfg.family)

    x = lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lyr.logits(params["embed"], x)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch: Dict, cache: Dict,
            *, remat: str = "none") -> Tuple[jax.Array, Dict]:
    """Prefill: single forward pass that also populates the decode cache."""
    if cfg.family in ("ssm", "hybrid"):
        # recurrent state prefill lives in serve/step.py (uses
        # ssm_block(return_state=True)); logits come from plain forward.
        logits, _ = forward(params, cfg, batch, remat=remat)
        return logits, cache

    x = lyr.embed(params["embed"], batch["tokens"])
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)
        pe = shard(pe, "batch", "act_seq", "act_embed")
        x = jnp.concatenate([pe, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    enc_out = None
    if cfg.family in ("encdec", "audio"):
        enc_out, _ = run_encoder(params, batch["src_embeds"].astype(
            jnp.bfloat16), cfg, remat)

    cross = cfg.family in ("encdec", "audio")

    def body(carry, lp):
        x, aux = carry
        x, a, k, v = _attn_block(lp, x, cfg, positions, causal=True,
                                 enc_out=enc_out, return_kv=True)
        aux = aux + a
        outs = (k, v)
        if cross:
            h = enc_out
            B, Ss = h.shape[0], h.shape[1]
            hd = cfg.resolved_head_dim
            kc = jnp.einsum("bsd,dh->bsh", h, lp["cross"]["wk"])
            vc = jnp.einsum("bsd,dh->bsh", h, lp["cross"]["wv"])
            outs = outs + (kc.reshape(B, Ss, cfg.n_kv_heads, hd),
                           vc.reshape(B, Ss, cfg.n_kv_heads, hd))
        return (x, aux), outs

    if remat != "none":
        body = jax.checkpoint(body)
    (x, _), outs = jax.lax.scan(body, (x, jnp.zeros((), F32)),
                                params["blocks"])
    x = lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lyr.logits(params["embed"], x)

    new_cache = dict(cache)
    s_max = cache["k"].shape[2]
    pad = [(0, 0), (0, 0), (0, s_max - S), (0, 0), (0, 0)]
    new_cache["k"] = jnp.pad(outs[0], pad).astype(cache["k"].dtype)
    new_cache["v"] = jnp.pad(outs[1], pad).astype(cache["v"].dtype)
    if cross:
        new_cache["ck"] = outs[2].astype(cache["ck"].dtype)
        new_cache["cv"] = outs[3].astype(cache["cv"].dtype)
    return logits, new_cache
