"""Parameter definition system.

Models declare their parameters once as a pytree of :class:`ParamDef`
(shape + logical axis names + initializer).  From that single source of
truth we derive:

* ``init_params``        — materialized arrays (seeded, per-leaf fold-in)
* ``abstract_params``    — ShapeDtypeStructs for the dry-run (no allocation)
* ``partition_specs``    — PartitionSpec pytree under a logical->mesh rule set

Logical axis vocabulary (see distributed/sharding.py for the rules):
  layers, embed, q_heads, kv_heads, mlp, vocab, expert, ssm_inner,
  ssm_state, conv, classes, pos
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | small
    scale: float = 1.0            # multiplier on the default fan-in scale
    dtype: Any = None             # None -> use global param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, d: ParamDef, dtype) -> jax.Array:
    dt = d.dtype or dtype
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32) * 0.02 * d.scale
                ).astype(dt)
    # fan-in scaled normal (truncation unnecessary at these scales)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dt)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(rng: jax.Array, defs, dtype=jnp.bfloat16):
    """Materialize a ParamDef pytree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_leaf(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree — feeds .lower() without any allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype),
        defs, is_leaf=_is_def)


def partition_specs(defs, rules: Dict[str, Any]):
    """Map logical axes -> mesh axes via ``rules`` (missing/None -> replicated)."""
    def spec(d: ParamDef) -> P:
        return P(*[rules.get(a) if a is not None else None for a in d.axes])
    return jax.tree.map(spec, defs, is_leaf=_is_def)


def param_count(defs) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree.leaves(defs, is_leaf=_is_def))


def param_bytes(defs, dtype=jnp.bfloat16) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=_is_def):
        dt = jnp.dtype(d.dtype or dtype)
        total += int(np.prod(d.shape)) * dt.itemsize
    return total


def stack_defs(defs, layers: int):
    """Prepend a scanned ``layers`` dimension to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: ParamDef((layers,) + d.shape, ("layers",) + d.axes,
                           init=d.init, scale=d.scale, dtype=d.dtype),
        defs, is_leaf=_is_def)


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def padded_vocab(vocab: int, multiple: int = 2048) -> int:
    """Pad vocab so embedding/logits shard 16-way with 128-lane alignment."""
    return round_up(vocab, multiple)
