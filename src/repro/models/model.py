"""Public model API: build(cfg) -> Model with init/loss/forward/decode +
``input_specs`` ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models.params import (ParamDef, abstract_params, init_params,
                                 param_count, partition_specs)

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- params ----
    def param_defs(self) -> Dict:
        return tfm.param_defs(self.cfg)

    def init(self, rng: jax.Array, dtype=jnp.bfloat16) -> Dict:
        return init_params(rng, self.param_defs(), dtype)

    def abstract(self, dtype=jnp.bfloat16) -> Dict:
        return abstract_params(self.param_defs(), dtype)

    def n_params(self) -> int:
        return param_count(self.param_defs())

    # ---- compute ----
    def loss(self, params, batch, *, remat: str = "none") -> jax.Array:
        return tfm.loss_fn(params, self.cfg, batch, remat=remat)

    def forward(self, params, batch, *, remat: str = "none"):
        return tfm.forward(params, self.cfg, batch, remat=remat)

    def prefill(self, params, batch, cache, *, remat: str = "none"):
        return tfm.prefill(params, self.cfg, batch, cache, remat=remat)

    def decode_step(self, params, cache, tokens, index):
        return tfm.decode_step(params, self.cfg, cache, tokens, index)

    # ---- caches ----
    def cache_defs(self, batch: int, s_max: int) -> Dict:
        return tfm.cache_defs(self.cfg, batch, s_max)

    def init_cache(self, batch: int, s_max: int) -> Dict:
        return init_params(jax.random.key(0), self.cache_defs(batch, s_max))

    def abstract_cache(self, batch: int, s_max: int) -> Dict:
        return abstract_params(self.cache_defs(batch, s_max))

    # ---- dry-run inputs ----
    def input_specs(self, shape: ShapeConfig) -> Dict[str, SDS]:
        """ShapeDtypeStruct stand-ins for every model input of a cell.

        train/prefill: the full-sequence batch.  decode: one new token
        (the KV cache is a separate argument; see abstract_cache).
        Modality frontends are stubs — [audio]/[vlm] specs contain
        precomputed frame/patch embeddings (DESIGN.md §2).
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32, bf16 = jnp.int32, jnp.bfloat16
        if shape.kind == "decode":
            return {"tokens": SDS((B, 1), i32)}
        if cfg.family == "encoder":
            spec = {"patch_embeds": SDS((B, cfg.frontend_tokens,
                                         cfg.d_model), bf16)}
            if shape.is_train:
                spec["labels"] = SDS((B,), i32)
            return spec
        if cfg.family == "vlm":
            p = cfg.frontend_tokens
            spec = {"tokens": SDS((B, S - p), i32),
                    "patch_embeds": SDS((B, p, cfg.d_model), bf16)}
            if shape.is_train:
                spec["labels"] = SDS((B, S), i32)
            return spec
        if cfg.family in ("encdec", "audio"):
            s_src = tfm.encdec_src_len(S)
            spec = {"tokens": SDS((B, S), i32),
                    "src_embeds": SDS((B, s_src, cfg.d_model), bf16)}
            if shape.is_train:
                spec["labels"] = SDS((B, S), i32)
            return spec
        spec = {"tokens": SDS((B, S), i32)}
        if shape.is_train:
            spec["labels"] = SDS((B, S), i32)
        return spec

    def batch_logical_axes(self, shape: ShapeConfig) -> Dict[str, Tuple]:
        """Logical sharding axes for each input (feeds in_shardings)."""
        cfg = self.cfg
        out: Dict[str, Tuple] = {}
        for name in self.input_specs(shape):
            if name in ("tokens", "labels"):
                if cfg.family == "encoder" and name == "labels":
                    out[name] = ("batch",)
                else:
                    out[name] = ("batch", "act_seq")
            elif name in ("patch_embeds", "src_embeds"):
                out[name] = ("batch", None, "act_embed")
        return out


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)


def make_batch(rng, model: Model, shape: ShapeConfig,
               reduced_shape: Optional[Tuple[int, int]] = None) -> Dict:
    """Random concrete batch matching input_specs (smoke tests/examples)."""
    cfg = model.cfg
    specs = model.input_specs(shape)
    if reduced_shape is not None:
        B, S = reduced_shape
        full = model.input_specs(shape)
        specs = {}
        for k, v in full.items():
            dims = list(v.shape)
            dims[0] = B
            if k in ("tokens", "labels") and len(dims) > 1 and \
                    cfg.family != "encoder":
                dims[1] = (S - cfg.frontend_tokens
                           if cfg.family == "vlm" and k == "tokens" else S)
            if k == "src_embeds":
                dims[1] = tfm.encdec_src_len(S)
            specs[k] = SDS(tuple(dims), v.dtype)
    batch = {}
    for k, v in specs.items():
        rng, sub = jax.random.split(rng)
        if v.dtype == jnp.int32:
            hi = cfg.n_classes if (cfg.family == "encoder" and k == "labels") \
                else cfg.vocab_size
            batch[k] = jax.random.randint(sub, v.shape, 0, hi, jnp.int32)
        else:
            batch[k] = jax.random.normal(sub, v.shape, jnp.float32).astype(
                v.dtype)
    return batch
