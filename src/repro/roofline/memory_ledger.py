"""Analytic HBM ledger per (arch x shape x layout): what lives on a chip.

Complements ``compiled.memory_analysis()`` (which reports what XLA-CPU
allocated) with a hardware-independent budget — params, gradients,
optimizer moments, KV/state caches and one microbatch of activations under
the cell's sharding — and answers the deployment question the dry-run
raises for the over-budget cells: *how many pods does this config need?*
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ParallelismConfig, ShapeConfig

HBM_PER_CHIP = 16e9          # v5e
CHIPS_PER_POD = 256


@dataclass
class Ledger:
    params: float
    grads: float
    opt_state: float
    cache_or_state: float
    activations: float

    @property
    def total(self) -> float:
        return (self.params + self.grads + self.opt_state
                + self.cache_or_state + self.activations)

    def fits(self, budget: float = HBM_PER_CHIP) -> bool:
        return self.total <= budget

    def pods_needed(self, chips_per_pod: int = CHIPS_PER_POD) -> int:
        """DP scale-out pods so the per-chip total fits HBM (activations
        shrink with pods; params/opt shrink only if FSDP spans pods)."""
        pods = 1
        while pods < 64:
            act = self.activations / pods
            fixed = self.params + self.grads + self.opt_state \
                + self.cache_or_state
            if fixed + act <= HBM_PER_CHIP:
                return pods
            pods *= 2
        return pods

    def as_dict(self) -> Dict[str, float]:
        return {"params_gb": self.params / 1e9,
                "grads_gb": self.grads / 1e9,
                "opt_gb": self.opt_state / 1e9,
                "cache_gb": self.cache_or_state / 1e9,
                "acts_gb": self.activations / 1e9,
                "total_gb": self.total / 1e9}


def build_ledger(cfg: ModelConfig, shape: ShapeConfig,
                 parallel: ParallelismConfig, chips: int = 256,
                 tp: int = 16, dp: int = 16) -> Ledger:
    n = cfg.n_params()
    pbytes = 2.0                                   # bf16 params
    shard = chips if parallel.fsdp else tp         # FSDP: all chips
    params = n * pbytes / shard

    if shape.is_train:
        grads = n * pbytes / shard
        opt_mult = {"float32": 8.0, "bfloat16": 4.0, "int8": 2.02}[
            parallel.opt_state_dtype]
        opt = n * opt_mult / shard
        cache = 0.0
        # one microbatch of residual-stream activations per layer
        # (remat=block keeps ~2 tensors/layer live; none keeps ~8)
        b_loc = max(shape.global_batch // dp, 1) // max(
            parallel.microbatches, 1)
        live = 2 if parallel.remat != "none" else 8
        layers = cfg.n_layers + cfg.n_encoder_layers
        acts = b_loc * shape.seq_len * cfg.d_model * 2.0 * live * \
            max(layers, 1) / max(layers, 1)        # scan reuses per layer
        acts *= live
    else:
        grads = opt = 0.0
        acts = 0.0
        hd = cfg.resolved_head_dim
        if cfg.family in ("dense", "moe", "vlm", "encdec", "audio"):
            kv = (cfg.n_layers * 2 * cfg.n_kv_heads * hd
                  * shape.seq_len * 2.0 * shape.global_batch)
            # decode cells shard batch over data and KV-seq/heads over model
            cache = kv / chips
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            cache = (cfg.n_layers * shape.global_batch * nh * s.head_dim
                     * s.d_state * 4.0) / max(dp, 1)
    return Ledger(params=params, grads=grads, opt_state=opt,
                  cache_or_state=cache, activations=acts)
