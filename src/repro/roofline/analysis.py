"""Roofline terms from the compiled dry-run artifact (TPU v5e targets).

    compute    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory     = HLO_bytes / (chips x 819 GB/s)
    collective = wire_bytes / (chips x 50 GB/s per ICI link)

cost_analysis() and the HLO module are per-device programs, so the
per-device numbers ARE the per-chip terms; chips enter when converting
model-level FLOPs (6ND) to per-chip work.

Known XLA caveat (measured in EXPERIMENTS.md §Dry-run): CPU-backend
cost_analysis does not multiply ``while``-loop bodies by trip count, so a
scan-over-layers program under-reports by ~n_layers.  We therefore report
BOTH the raw cost_analysis numbers and analytic MODEL_FLOPS (6·N·D dense /
6·N_active·D MoE + attention) and derive the roofline from whichever is
self-consistent (see ``flops_source`` in each record).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (1 link per axis-neighbor)
DCN_BW = 25e9                # B/s per pod for the 'pod' axis


@dataclass
class RooflineRecord:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw artifact numbers (per device)
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    wire_bytes_per_dev: float
    collectives: Dict[str, float]
    # analytic
    model_flops: float               # global, 6ND(+attn) per step
    flops_source: str
    # derived terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0        # MODEL_FLOPS / (HLO flops global)
    roofline_fraction: float = 0.0   # t_compute / max(all terms)
    note: str = ""

    def finalize(self) -> "RooflineRecord":
        hlo_global = self.hlo_flops_per_dev * self.chips
        self.t_compute = self.hlo_flops_per_dev / PEAK_FLOPS
        self.t_memory = self.hlo_bytes_per_dev / HBM_BW
        self.t_collective = self.wire_bytes_per_dev / ICI_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / hlo_global
                             if hlo_global else 0.0)
        tmax = max(terms.values())
        self.roofline_fraction = self.t_compute / tmax if tmax else 0.0
        return self


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Dot-product attention FLOPs per training/prefill step (fwd only)."""
    if cfg.n_heads == 0:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    layers = cfg.n_layers + cfg.n_encoder_layers
    # causal: S^2/2 per pair of (qk, av) matmuls
    return 2.0 * layers * B * (S * S / 2) * cfg.n_heads * hd * 2


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) + attention term.

    Training: 6ND (fwd+bwd).  Prefill: 2ND (fwd only).  Decode: 2N per
    token x batch.
    """
    n_active = cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        flops = 6.0 * n_active * B * S + 3.0 * attention_flops(cfg, shape)
    elif shape.kind == "prefill":
        flops = 2.0 * n_active * B * S + attention_flops(cfg, shape)
    else:  # decode: one token per sequence; attention reads the S-cache
        hd = cfg.resolved_head_dim
        attn = (2.0 * cfg.n_layers * B * S * cfg.n_heads * hd * 2
                if cfg.n_heads else 0.0)
        flops = 2.0 * n_active * B + attn
    return flops


def build_record(*, arch: str, shape: ShapeConfig, cfg: ModelConfig,
                 mesh_name: str, chips: int, cost: Dict,
                 wire_bytes: float, collectives: Dict[str, float],
                 note: str = "") -> RooflineRecord:
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    mf = model_flops(cfg, shape)
    # XLA-CPU cost_analysis does not multiply while-loop (scan) bodies;
    # detect gross under-count and substitute the analytic floor.
    src = "cost_analysis"
    if hlo_flops * chips < 0.5 * mf:
        hlo_flops = mf / chips
        src = "analytic_6ND(cost_analysis_undercounts_loops)"
    rec = RooflineRecord(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops_per_dev=hlo_flops, hlo_bytes_per_dev=hlo_bytes,
        wire_bytes_per_dev=wire_bytes, collectives=dict(collectives),
        model_flops=mf, flops_source=src, note=note)
    return rec.finalize()
