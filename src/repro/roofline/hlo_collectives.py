"""Collective-byte accounting from compiled HLO text.

``cost_analysis()`` does not expose collective traffic, so we parse the
post-SPMD module: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op is matched, its result shape sized, and
ring-algorithm wire-byte factors applied per op kind and replica-group
size.  Numbers are per-device (the SPMD module is a per-device program).

Loop awareness: a scan-over-layers program holds its per-layer collectives
inside a ``while`` body that executes ``n_layers`` times.  We segment the
module into computations, extract each while loop's trip count from its
condition's comparison constant, and multiply collective bytes by the
product of enclosing trip counts (nested scans compose, e.g. microbatch
accumulation x layers).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.:  %ag = bf16[16,1024]{1,0} all-gather(...), replica_groups={{0,1,..}}
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^ ]*\s*,?\s*)+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
# iota form: replica_groups=[n_groups,group_size]<=[...]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shapes_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    per_kind_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    per_kind_count: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.per_kind_bytes.values())

    def summary(self) -> Dict[str, float]:
        out = {f"{k}_bytes": v for k, v in self.per_kind_bytes.items()}
        out.update({f"{k}_count": v for k, v in self.per_kind_count.items()})
        out["total_wire_bytes"] = self.total_wire_bytes
        return out


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{$")
_WHILE_RE = re.compile(
    r"while\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _wire_bytes(line: str, kind: str) -> float:
    m = _OP_RE.search(line)
    shapes_str = m.group(1)
    out_bytes = _shape_bytes(shapes_str)
    g = 2
    gm = _GROUPS_IOTA_RE.search(line)
    if gm:
        g = max(int(gm.group(2)), 2)
    else:
        gm = _GROUPS_RE.search(line)
        if gm:
            g = max(len(gm.group(1).split(",")), 2)
    if kind == "all-reduce":
        return out_bytes * 2.0 * (g - 1) / g
    if kind == "all-gather":
        return out_bytes * (g - 1) / g            # output = gathered size
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)                # output = scattered shard
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    return out_bytes                              # collective-permute


def _segment(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> its lines."""
    comps: Dict[str, List[str]] = {}
    cur: List[str] = []
    name = "__preamble__"
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and "{" in line:
            name = m.group(1)
            cur = []
            comps[name] = cur
        else:
            cur.append(line) if name in comps else None
    return comps


def analyze(hlo_text: str) -> CollectiveStats:
    comps = _segment(hlo_text)
    if not comps:
        comps = {"__all__": hlo_text.splitlines()}

    # trip count of each while condition: the largest int constant compared
    trip_of_cond: Dict[str, int] = {}
    for cname, lines in comps.items():
        consts = [int(c) for ln in lines for c in _CONST_RE.findall(ln)]
        trip_of_cond[cname] = max(consts) if consts else 1

    # per-computation: own collectives + callees with multipliers
    own: Dict[str, CollectiveStats] = {}
    calls: Dict[str, List[Tuple[str, int]]] = {}
    for cname, lines in comps.items():
        st = CollectiveStats()
        cl: List[Tuple[str, int]] = []
        for line in lines:
            m = _OP_RE.search(line)
            if m:
                kind = m.group(2)
                st.per_kind_bytes[kind] += _wire_bytes(line, kind)
                st.per_kind_count[kind] += 1
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                cl.append((body, max(trip_of_cond.get(cond, 1), 1)))
            else:
                for callee in _CALL_RE.findall(line):
                    cl.append((callee, 1))
        own[cname] = st
        calls[cname] = cl

    # entry = computation that nobody calls (fall back to the largest)
    called = {b for cl in calls.values() for b, _ in cl}
    roots = [c for c in comps if c not in called]
    entry = max(roots or comps, key=lambda c: len(comps[c]))

    total = CollectiveStats()
    seen: set = set()

    def accumulate(cname: str, mult: float, depth: int = 0) -> None:
        if depth > 12 or cname not in own:
            return
        key = (cname, round(mult, 3))
        st = own[cname]
        for k, v in st.per_kind_bytes.items():
            total.per_kind_bytes[k] += v * mult
        for k, v in st.per_kind_count.items():
            total.per_kind_count[k] += int(v * mult)
        for callee, trips in calls[cname]:
            accumulate(callee, mult * trips, depth + 1)

    accumulate(entry, 1.0)
    return total
