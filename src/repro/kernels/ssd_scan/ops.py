"""Public op: SSD scan entry point with kernel/reference dispatch."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, *, chunk: int = 128, use_kernel: bool = True,
        interpret: bool = True):
    if use_kernel:
        return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    return ssd_ref(x, dt, A, Bm, Cm)
