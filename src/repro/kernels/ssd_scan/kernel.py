"""Mamba2 SSD chunked-scan Pallas kernel.

Grid = (batch, head).  Each program owns one (b, h) stream: the sequence is
processed chunk-by-chunk with the (P x N) state carried in VMEM scratch.
Per chunk the kernel does the dense intra-chunk quadratic form (two MXU
matmuls over (c x c)) plus the state update — the same math as
``models/ssm._ssd_core`` but with the (B, nc, c, c, nh) decay tensor never
leaving VMEM, which is the TPU adaptation of the paper-adjacent Triton
kernel (HBM traffic drops from O(S^2/c * nh) to O(S * (P + N))).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                h_ref, *, chunk: int, n_chunks: int):
    A = a_ref[0]                                    # scalar for this head
    h_ref[...] = jnp.zeros_like(h_ref)              # fresh state per (b, h)

    def body(ci, _):
        sl = pl.ds(ci * chunk, chunk)
        x = x_ref[0, sl, 0, :].astype(jnp.float32)        # (c, P)
        dt = dt_ref[0, sl, 0].astype(jnp.float32)         # (c,)
        Bm = b_ref[0, sl, :].astype(jnp.float32)          # (c, N)
        Cm = c_ref[0, sl, :].astype(jnp.float32)          # (c, N)

        dA = dt * A                                       # (c,) negative
        cum = jnp.cumsum(dA)
        seg = cum[-1]

        # intra-chunk: y_i = sum_{j<=i} C_i.B_j exp(cum_i-cum_j) dt_j x_j
        li = cum[:, None]
        lj = cum[None, :]
        mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
            jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        decay = jnp.where(mask, jnp.exp(li - lj), 0.0)
        cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))
        w = cb * decay                                    # (c, c)
        xdt = x * dt[:, None]
        y = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())))

        # inter-chunk: contribution of carried state
        h = h_ref[...]                                    # (P, N)
        y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
            Cm, h, (((1,), (1,)), ((), ())))

        # state update: h' = exp(seg) h + sum_j exp(seg-cum_j) dt_j x_j B_j
        sdecay = jnp.exp(seg - cum) * dt                  # (c,)
        upd = jax.lax.dot_general(x * sdecay[:, None], Bm,
                                  (((0,), (0,)), ((), ())))  # (P, N)
        h_ref[...] = jnp.exp(seg) * h + upd
        y_ref[0, sl, 0, :] = y.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, n_chunks, body, ())
    hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 128,
             interpret: bool = True):
    """x: (B,S,nh,P); dt: (B,S,nh); A: (nh,); Bm/Cm: (B,S,N).

    Returns (y (B,S,nh,P), h_final (B,nh,P,N)).
    """
    Bsz, S, nh, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    kernel = functools.partial(_ssd_kernel, chunk=chunk,
                               n_chunks=S // chunk)
    y, h = pl.pallas_call(
        kernel,
        grid=(Bsz, nh),
        in_specs=[
            pl.BlockSpec((1, S, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, S, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1,), lambda b, h: (h,)),
            pl.BlockSpec((1, S, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, S, N), lambda b, h: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, nh, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, nh, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, h
