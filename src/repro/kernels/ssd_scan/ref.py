"""Pure-jnp oracle for the SSD chunked scan: the naive O(S) recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array, h0=None):
    """Sequential SSD recurrence (ground truth).

    x: (B, S, nh, P); dt: (B, S, nh) post-softplus; A: (nh,) negative;
    Bm/Cm: (B, S, N).  Returns (y (B,S,nh,P), h_final (B,nh,P,N)).
    """
    Bsz, S, nh, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,nh,P),(B,nh),(B,N)x2
        decay = jnp.exp(dtt * A)                    # (B, nh)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final
