"""Shared device probe for the Pallas kernels.

Every kernel entry point auto-selects ``interpret`` mode when the caller
passes ``None``: compiled Mosaic on TPU, the Pallas interpreter everywhere
else (CPU CI / tests).  The probe used to run per ``augment`` call —
``jax.default_backend()`` walks the backend registry every batch — so it
is hoisted here behind a cache shared by the augment and decode kernels.
"""
from __future__ import annotations

import functools
from typing import Optional


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True when Pallas kernels should run in interpret mode (non-TPU).

    Cached for the process lifetime: the default backend cannot change
    after the first JAX computation anyway.
    """
    import jax

    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> the cached probe; explicit flags pass through."""
    return default_interpret() if interpret is None else bool(interpret)
