"""Pallas decode + fused decode/augment kernels.

One grid step synthesizes one image: the counter hash runs over a
``broadcasted_iota`` index cube, so there is no source tile to stage — the
"decode" reads nothing but two scalars per sample (base seed + header
mix).  The fused variant hashes *only the crop window's* source indices
(mirrored columns under flip) and feeds the exact float pipeline of the
augment kernel, emitting the normalized crop with no intermediate decoded
image anywhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.augment.kernel import MEAN, STD
from repro.kernels.decode.ref import pixel_hash_jnp
from repro.kernels.device import resolve_interpret


def _decode_kernel(base_ref, mix_ref, out_ref, *, h: int, w: int):
    base = base_ref[0]
    mix = mix_ref[0]
    row = jax.lax.broadcasted_iota(jnp.uint32, (h, w, 3), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (h, w, 3), 1)
    chan = jax.lax.broadcasted_iota(jnp.uint32, (h, w, 3), 2)
    idx = (row * jnp.uint32(w) + col) * jnp.uint32(3) + chan
    u8 = pixel_hash_jnp(base, idx).astype(jnp.int32)
    out_ref[0] = ((u8 + mix) % 256).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("h", "w", "interpret"))
def decode(bases: jax.Array, mixes: jax.Array, *, h: int, w: int,
           interpret: Optional[bool] = None) -> jax.Array:
    """(B,) uint32 base seeds + (B,) int32 header mixes -> (B,h,w,3) uint8.

    Byte-identical to ``SyntheticDataset.decode`` per sample (pinned by
    tests/test_decode_kernel.py).
    """
    interpret = resolve_interpret(interpret)
    B = bases.shape[0]
    kernel = functools.partial(_decode_kernel, h=h, w=w)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, h, w, 3), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, h, w, 3), jnp.uint8),
        interpret=interpret,
    )(bases.astype(jnp.uint32), mixes.astype(jnp.int32))


def _decode_augment_kernel(base_ref, mix_ref, top_ref, left_ref, flip_ref,
                           out_ref, *, img_w: int, crop_h: int,
                           crop_w: int):
    base = base_ref[0]
    mix = mix_ref[0]
    top = top_ref[0]
    left = left_ref[0]
    flip = flip_ref[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (crop_h, crop_w, 3), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (crop_h, crop_w, 3), 1)
    c = jax.lax.broadcasted_iota(jnp.int32, (crop_h, crop_w, 3), 2)
    # the flip is a source-index mirror: hash the pixel the flipped crop
    # would have read, instead of materializing then reversing
    src_j = jnp.where(flip != 0, crop_w - 1 - j, j)
    row = (top + i).astype(jnp.uint32)
    col = (left + src_j).astype(jnp.uint32)
    idx = (row * jnp.uint32(img_w) + col) * jnp.uint32(3) \
        + c.astype(jnp.uint32)
    u8 = pixel_hash_jnp(base, idx).astype(jnp.int32)
    pix = (u8 + mix) % 256
    # from here: the augment kernel's exact float pipeline (/255, scalar
    # per-channel normalize) so fused == decode-then-augment bitwise
    x = pix.astype(jnp.float32) / 255.0
    chans = [(x[:, :, ch] - MEAN[ch]) / STD[ch] for ch in range(3)]
    out_ref[0] = jnp.stack(chans, axis=-1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("img_h", "img_w", "crop_h",
                                             "crop_w", "out_dtype",
                                             "interpret"))
def decode_augment(bases: jax.Array, mixes: jax.Array, tops: jax.Array,
                   lefts: jax.Array, flips: jax.Array, *, img_h: int,
                   img_w: int, crop_h: int, crop_w: int,
                   out_dtype=jnp.float32,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Fused decode+crop+flip+normalize: per-sample scalars in, augmented
    (B,crop_h,crop_w,3) out — one kernel, one device round-trip."""
    interpret = resolve_interpret(interpret)
    del img_h  # part of the contract/signature; only img_w indexes memory
    B = bases.shape[0]
    kernel = functools.partial(_decode_augment_kernel, img_w=img_w,
                               crop_h=crop_h, crop_w=crop_w)
    scalar = pl.BlockSpec((1,), lambda b: (b,))
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[scalar] * 5,
        out_specs=pl.BlockSpec((1, crop_h, crop_w, 3),
                               lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, crop_h, crop_w, 3), out_dtype),
        interpret=interpret,
    )(bases.astype(jnp.uint32), mixes.astype(jnp.int32),
      tops.astype(jnp.int32), lefts.astype(jnp.int32),
      flips.astype(jnp.int32))
