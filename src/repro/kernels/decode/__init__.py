"""Device-side 'JPEG decode' kernels (counter-hash pixel synthesis).

``repro.data.synthetic.SyntheticDataset.decode`` derives every pixel byte
from a splitmix32-style counter hash plus a payload-header mix; this
package reproduces that math bit-for-bit on device — standalone
(:func:`ops.decode_batch`) or fused with crop/flip/normalize
(:func:`repro.kernels.augment.ops.decode_augment_batch_seeded`), so the
augmented tensor is produced in one device round-trip with no host-side
decoded image at all.
"""
from repro.kernels.decode.ops import (decode_batch, decode_params,
                                      fused_decode_seed)

__all__ = ["decode_batch", "decode_params", "fused_decode_seed"]
