"""jnp oracle for the decode kernel (and the shared hash body).

:func:`pixel_hash_jnp` is the device twin of
:func:`repro.data.synthetic.pixel_hash`: identical constants, identical
uint32 wraparound, so host and device decode agree byte-for-byte.  The
Pallas kernel calls the same function inside its body — pure ``jnp`` ops
lower fine under ``pallas_call`` — keeping exactly one device copy of the
mixer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.synthetic import _HASH_M1, _HASH_M2, _HASH_STEP


def pixel_hash_jnp(base: jax.Array, idx: jax.Array) -> jax.Array:
    """uint32 pixel-byte stream (low 8 bits significant) for counter
    indices ``idx`` under per-sample seed ``base`` (both uint32)."""
    x = base + idx * jnp.uint32(_HASH_STEP)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(_HASH_M1)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(_HASH_M2)
    x = x ^ (x >> jnp.uint32(16))
    return x & jnp.uint32(0xFF)


def _decode_one(base: jax.Array, mix: jax.Array, h: int, w: int
                ) -> jax.Array:
    idx = jnp.arange(h * w * 3, dtype=jnp.uint32)
    u8 = pixel_hash_jnp(base, idx).astype(jnp.int32)
    return ((u8 + mix) % 256).astype(jnp.uint8).reshape(h, w, 3)


def decode_ref(bases: jax.Array, mixes: jax.Array, h: int, w: int
               ) -> jax.Array:
    """(B,) uint32 bases + (B,) int32 header mixes -> (B,h,w,3) uint8."""
    return jax.vmap(lambda b, m: _decode_one(b, m, h, w))(
        bases.astype(jnp.uint32), mixes.astype(jnp.int32))
