"""Public decode ops: host param derivation + batched device decode.

The device decode contract is two scalars per sample — the counter-hash
base seed and the payload-header mix — both derived here on host from the
dataset seed and the encoded byte buffers (:func:`decode_params`), so the
kernel never sees the payload itself.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import FileDataset, SyntheticDataset
from repro.kernels.decode.kernel import decode as _decode_kernel_call


def decode_params(seed: int, sample_ids: Sequence[int],
                  payloads: Sequence[bytes]
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """(bases uint32[B], mixes int32[B]) for a batch of encoded buffers
    under dataset ``seed`` — the host half of the kernel contract,
    byte-compatible with ``SyntheticDataset.decode_base_seed`` /
    ``decode_head_mix``."""
    bases = np.fromiter(((seed * 31 + int(s)) & 0xFFFFFFFF
                         for s in sample_ids), np.uint32,
                        count=len(sample_ids))
    mixes = np.fromiter((SyntheticDataset.decode_head_mix(p)
                         for p in payloads), np.int32,
                        count=len(payloads))
    return bases, mixes


def fused_decode_seed(ds) -> Optional[int]:
    """The dataset seed when ``ds.decode`` is exactly the base
    counter-hash decode (so the device kernel can substitute for it),
    else None.  Subclasses that override ``decode`` (e.g.
    ``DecodeHeavyDataset``) are rejected; ``FileDataset`` delegates to
    its base, so it qualifies when the base does."""
    base = ds.base if isinstance(ds, FileDataset) else ds
    if type(base) is SyntheticDataset:
        return int(base.seed)
    return None


def decode_batch(payloads: Sequence[bytes], sample_ids: Sequence[int], *,
                 seed: int, image_hw: Tuple[int, int],
                 interpret: Optional[bool] = None) -> np.ndarray:
    """Batched device decode -> (B,h,w,3) uint8 host array, byte-identical
    to per-sample ``SyntheticDataset.decode``."""
    bases, mixes = decode_params(seed, sample_ids, payloads)
    h, w = image_hw
    out = _decode_kernel_call(jnp.asarray(bases), jnp.asarray(mixes),
                              h=h, w=w, interpret=interpret)
    return np.asarray(out)


def decode_batch_ref(payloads: Sequence[bytes],
                     sample_ids: Sequence[int], *, seed: int,
                     image_hw: Tuple[int, int]) -> jax.Array:
    """jnp oracle twin of :func:`decode_batch` (tests)."""
    from repro.kernels.decode.ref import decode_ref
    bases, mixes = decode_params(seed, sample_ids, payloads)
    h, w = image_hw
    return decode_ref(jnp.asarray(bases), jnp.asarray(mixes), h, w)
