"""Public op: device-side fused augmentation with PRNG-driven parameters.

``augment_batch(rng, images, crop)`` derives per-sample crop offsets and
flips from a JAX key and dispatches to the Pallas kernel (interpret mode on
CPU; compiled on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.augment.kernel import augment
from repro.kernels.augment.ref import augment_ref


def augment_batch(rng: jax.Array, images: jax.Array, crop_h: int,
                  crop_w: int, *, use_kernel: bool = True,
                  interpret: bool = True,
                  out_dtype=jnp.bfloat16) -> jax.Array:
    B, H, W, _ = images.shape
    k1, k2, k3 = jax.random.split(rng, 3)
    tops = jax.random.randint(k1, (B,), 0, H - crop_h + 1, jnp.int32)
    lefts = jax.random.randint(k2, (B,), 0, W - crop_w + 1, jnp.int32)
    flips = jax.random.bernoulli(k3, 0.5, (B,))
    if use_kernel:
        return augment(images, tops, lefts, flips.astype(jnp.int32),
                       crop_h=crop_h, crop_w=crop_w, out_dtype=out_dtype,
                       interpret=interpret)
    return augment_ref(images, tops, lefts, flips, crop_h, crop_w,
                       out_dtype=out_dtype)
