"""Public ops: device-side fused augmentation.

``augment_batch(rng, images, crop)`` derives per-sample crop offsets and
flips from a JAX key and dispatches to the Pallas kernel (interpret mode on
CPU; compiled on TPU).

``augment_batch_seeded(images, seeds, ...)`` is the live-pipeline entry
point: the geometric parameters are derived *on host* from per-sample
integer seeds with the exact draw sequence of
:func:`repro.data.augment.augment_np`, so the kernel output matches the
NumPy fallback per sample (same seed -> same crop/flip, float32 math on
both sides) regardless of how samples are batched together.

``decode_augment_batch_seeded(payloads, sample_ids, seeds, ...)`` goes one
step further for counter-hash datasets: encoded byte buffers in, augmented
device crops out, with decode and augment fused into one Pallas kernel —
the host ships only per-sample scalars (seed base, header mix, crop
params), never a decoded image.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.augment import derive_batch_params
from repro.kernels.augment.kernel import augment
from repro.kernels.augment.ref import augment_ref


def augment_batch(rng: jax.Array, images: jax.Array, crop_h: int,
                  crop_w: int, *, use_kernel: bool = True,
                  interpret: Optional[bool] = None,
                  out_dtype=jnp.bfloat16) -> jax.Array:
    B, H, W, _ = images.shape
    k1, k2, k3 = jax.random.split(rng, 3)
    tops = jax.random.randint(k1, (B,), 0, H - crop_h + 1, jnp.int32)
    lefts = jax.random.randint(k2, (B,), 0, W - crop_w + 1, jnp.int32)
    flips = jax.random.bernoulli(k3, 0.5, (B,))
    if use_kernel:
        return augment(images, tops, lefts, flips.astype(jnp.int32),
                       crop_h=crop_h, crop_w=crop_w, out_dtype=out_dtype,
                       interpret=interpret)
    return augment_ref(images, tops, lefts, flips, crop_h, crop_w,
                       out_dtype=out_dtype)


def _pad_to_bucket(n: int) -> int:
    """Next power-of-two batch bucket, so variable-size augment groups
    (cache hits shrink them) reuse a handful of kernel traces instead of
    retracing per distinct B."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def augment_batch_seeded(images: np.ndarray, seeds: np.ndarray,
                         crop_h: int, crop_w: int, *,
                         out_dtype=jnp.float32,
                         interpret: Optional[bool] = None,
                         bucket: Optional[int] = None,
                         as_device: bool = False) -> np.ndarray:
    """(B,H,W,3) uint8 + per-sample seeds -> (B,crop_h,crop_w,3) host array.

    Batches are padded up to power-of-two buckets (rows repeated, result
    sliced back) to bound jit retraces across ragged group sizes;
    ``bucket`` overrides the target size (callers pass ``bucket=B`` for
    sizes they know recur, e.g. the full batch, so a 12-sample batch is
    not padded to 16 forever).  ``as_device`` skips the final host copy
    and returns the sliced device array — the device-path executor
    admits those rows into the HBM tier zero-copy.

    ``images`` may be a device-resident ``jax.Array`` (HBM-tier decoded
    hits): it is padded and fed to the kernel on device, with no host
    round-trip.
    """
    on_device = isinstance(images, jax.Array)
    if not on_device:
        images = np.ascontiguousarray(images)
    B, H, W, _ = images.shape
    tops, lefts, flips = derive_batch_params(
        (H, W), (crop_h, crop_w), np.asarray(seeds))
    Bp = max(bucket, B) if bucket else _pad_to_bucket(B)
    if Bp != B:
        pad = [(0, Bp - B)] + [(0, 0)] * (images.ndim - 1)
        images = (jnp if on_device else np).pad(images, pad, mode="edge")
        tops = np.pad(tops, (0, Bp - B), mode="edge")
        lefts = np.pad(lefts, (0, Bp - B), mode="edge")
        flips = np.pad(flips, (0, Bp - B), mode="edge")
    out = augment(jnp.asarray(images), jnp.asarray(tops),
                  jnp.asarray(lefts), jnp.asarray(flips),
                  crop_h=crop_h, crop_w=crop_w, out_dtype=out_dtype,
                  interpret=interpret)
    return out[:B] if as_device else np.asarray(out[:B])


def decode_augment_batch_seeded(payloads: Sequence[bytes],
                                sample_ids: Sequence[int],
                                seeds: np.ndarray, *, ds_seed: int,
                                image_hw: Tuple[int, int], crop_h: int,
                                crop_w: int, out_dtype=jnp.float32,
                                interpret: Optional[bool] = None,
                                bucket: Optional[int] = None) -> jax.Array:
    """Encoded byte buffers + per-sample augment seeds -> augmented
    (B,crop_h,crop_w,3) crops as a *device* array, decode and augment
    fused into one kernel launch.

    Crop/flip params come from the exact :func:`crop_flip_params` draw
    sequence (via ``derive_batch_params``), and the decode half is the
    counter hash of ``SyntheticDataset.decode`` — so per sample the
    result equals ``augment_batch_seeded(decode(payload), seed)``
    bitwise (pinned by tests/test_decode_kernel.py).  Same power-of-two
    bucket padding as :func:`augment_batch_seeded`; the output stays on
    device so an HBM cache tier can admit it zero-copy.
    """
    from repro.kernels.decode.ops import decode_params
    B = len(payloads)
    bases, mixes = decode_params(ds_seed, sample_ids, payloads)
    H, W = image_hw
    tops, lefts, flips = derive_batch_params(
        (H, W), (crop_h, crop_w), np.asarray(seeds))
    Bp = max(bucket, B) if bucket else _pad_to_bucket(B)
    if Bp != B:
        bases = np.pad(bases, (0, Bp - B), mode="edge")
        mixes = np.pad(mixes, (0, Bp - B), mode="edge")
        tops = np.pad(tops, (0, Bp - B), mode="edge")
        lefts = np.pad(lefts, (0, Bp - B), mode="edge")
        flips = np.pad(flips, (0, Bp - B), mode="edge")
    from repro.kernels.decode.kernel import decode_augment
    out = decode_augment(jnp.asarray(bases), jnp.asarray(mixes),
                         jnp.asarray(tops), jnp.asarray(lefts),
                         jnp.asarray(flips), img_h=H, img_w=W,
                         crop_h=crop_h, crop_w=crop_w,
                         out_dtype=out_dtype, interpret=interpret)
    return out[:B]
