"""Pure-jnp oracle for the fused augmentation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

MEAN = jnp.array([0.485, 0.456, 0.406], jnp.float32)
STD = jnp.array([0.229, 0.224, 0.225], jnp.float32)


def augment_ref(images: jax.Array, tops: jax.Array, lefts: jax.Array,
                flips: jax.Array, crop_h: int, crop_w: int,
                out_dtype=jnp.bfloat16) -> jax.Array:
    """Dequantize + crop + horizontal flip + normalize.

    images: (B, H, W, 3) uint8;  tops/lefts: (B,) int32;  flips: (B,) bool.
    Returns (B, crop_h, crop_w, 3) ``out_dtype``.
    """
    def one(img, top, left, flip):
        crop = jax.lax.dynamic_slice(img, (top, left, 0),
                                     (crop_h, crop_w, 3))
        crop = jnp.where(flip, crop[:, ::-1, :], crop)
        x = crop.astype(jnp.float32) / 255.0
        return ((x - MEAN) / STD).astype(out_dtype)

    return jax.vmap(one)(images, tops, lefts, flips)
