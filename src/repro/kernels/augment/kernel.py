"""Fused augmentation Pallas kernel (the paper's preprocessing hot-spot,
made TPU-native — DESIGN.md §7).

One grid step processes one image: the uint8 source tile is staged in VMEM,
the random crop is a dynamic slice, the flip is a lane reversal, and
dequantize+normalize fuse into the store.  Output feeds the model in bf16,
so the host never touches fp32 tensors (4x PCIe traffic saved vs the
paper's fp32 pipeline — this is the kernel's roofline argument: the op is
memory-bound, bytes_out drop 4x).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.device import resolve_interpret

MEAN = (0.485, 0.456, 0.406)
STD = (0.229, 0.224, 0.225)


def _augment_kernel(img_ref, top_ref, left_ref, flip_ref, out_ref, *,
                    crop_h: int, crop_w: int):
    top = top_ref[0]
    left = left_ref[0]
    flip = flip_ref[0]
    img = img_ref[0]                                   # (H, W, 3) uint8
    crop = jax.lax.dynamic_slice(
        img, (top, left, 0), (crop_h, crop_w, 3)).astype(jnp.float32)
    crop = jax.lax.cond(flip != 0,
                        lambda c: jax.lax.rev(c, (1,)),
                        lambda c: c, crop)
    x = crop / 255.0
    # per-channel normalize with scalar constants (pallas kernels cannot
    # capture array constants)
    chans = [(x[:, :, c] - MEAN[c]) / STD[c] for c in range(3)]
    out_ref[0] = jnp.stack(chans, axis=-1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("crop_h", "crop_w",
                                             "out_dtype", "interpret"))
def augment(images: jax.Array, tops: jax.Array, lefts: jax.Array,
            flips: jax.Array, *, crop_h: int, crop_w: int,
            out_dtype=jnp.bfloat16,
            interpret: Optional[bool] = None) -> jax.Array:
    """images (B,H,W,3) uint8 -> (B,crop_h,crop_w,3) out_dtype.

    ``interpret=None`` (default) auto-selects via the cached module-level
    probe (repro.kernels.device): compiled Mosaic on TPU, interpreter
    everywhere else (CPU CI / tests).  The flag is static, so the choice
    is resolved once per (shape, dtype) trace.
    """
    interpret = resolve_interpret(interpret)
    B, H, W, C = images.shape
    assert C == 3
    kernel = functools.partial(_augment_kernel, crop_h=crop_h, crop_w=crop_w)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, 3), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, crop_h, crop_w, 3),
                               lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, crop_h, crop_w, 3), out_dtype),
        interpret=interpret,
    )(images, tops.astype(jnp.int32), lefts.astype(jnp.int32),
      flips.astype(jnp.int32))
