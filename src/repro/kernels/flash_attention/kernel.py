"""Blockwise causal flash attention (forward) in Pallas.

MXU-aligned (q_block x k_block = 128x128 by default) tiles with the online
softmax recurrence; running (max, sum, acc) state lives in VMEM scratch.
The kv loop is the innermost grid dimension, so each (batch*head, q_block)
pair streams K/V tiles HBM->VMEM exactly once.

Causality is exploited structurally: kv blocks strictly above the diagonal
are skipped via ``pl.when`` (no wasted MXU work — this halves the FLOPs vs
a masked dense pass and is the kernel's main roofline win at 32k prefill).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  q_block: int, k_block: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip fully-masked blocks (strictly above the causal diagonal)
    if causal:
        active = ki * k_block <= qi * q_block + q_block - 1
    else:
        active = ki >= 0

    @pl.when(active)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (qb, hd)
        k = k_ref[0].astype(jnp.float32)              # (kb, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = qi * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, k_block), 0)
            kpos = ki * k_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, k_block), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "k_block",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_block: int = 128,
                    k_block: int = 128, interpret: bool = True) -> jax.Array:
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    q_block = min(q_block, S)
    k_block = min(k_block, S)
    assert S % q_block == 0 and S % k_block == 0
    grid = (B * H, S // q_block, S // k_block)
    qr = q.reshape(B * H, S, hd)
    kr = k.reshape(B * H, S, hd)
    vr = v.reshape(B * H, S, hd)
    kernel = functools.partial(
        _flash_kernel, q_block=q_block, k_block=k_block, causal=causal,
        scale=1.0 / (hd ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd)
