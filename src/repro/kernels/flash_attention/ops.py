"""Public op: GQA-aware flash attention dispatch.

``flash_mha(q, k, v)`` accepts model-layout (B, S, H, hd) tensors with
grouped KV heads, expands the grouping, and calls the Pallas kernel
(interpret on CPU, compiled on TPU).  Set ``attn_impl="splash"`` in
ParallelismConfig to route model attention here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd) with H % K == 0."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:                       # expand grouped KV heads
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    qt = jnp.swapaxes(q, 1, 2)       # (B, H, S, hd)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, causal=causal, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
