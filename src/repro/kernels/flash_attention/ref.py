"""Pure-jnp oracle for blockwise causal attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q/k/v: (B, H, S, hd).  fp32 softmax, output in q.dtype."""
    S = q.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    scores = scores / (q.shape[-1] ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), v)
