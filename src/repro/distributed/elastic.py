"""Elastic scaling: re-mesh and reshard when the node count changes.

On failure (or capacity change) the runtime rebuilds the mesh at the new
size and moves every array to its new NamedSharding.  The *logical* rules
(distributed/sharding.py) are size-independent, so the resharding plan is
just "same spec, new mesh"; divisibility is re-validated and axes whose
factor no longer divides fall back to replication (recorded in the plan).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class RemeshPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    demotions: List[str]              # param paths that lost an axis

    def summary(self) -> str:
        return (f"{self.old_shape} -> {self.new_shape} on "
                f"{self.axis_names}; {len(self.demotions)} demotions")


def make_mesh(n_devices: int, axis_names=("data", "model"),
              model_parallel: int = 0) -> Mesh:
    devs = jax.devices()[:n_devices]
    mp = model_parallel or min(n_devices, 16)
    while n_devices % mp:
        mp -= 1
    shape = (n_devices // mp, mp)
    return Mesh(np.asarray(devs).reshape(shape), axis_names)


def shrunk_mesh(n_devices: int, failed: Any,
                axis_names=("data", "model"),
                model_parallel: int = 0) -> Mesh:
    """Rebuild the mesh with the failed hosts removed.

    ``failed`` is either an iterable of dead device/host indices or a
    liveness registry (anything with a ``failed()`` method — the
    :class:`~repro.faults.liveness.LivenessRegistry` the trainer's
    heartbeats now ride on), so the elastic path consumes failure
    detection directly instead of a hand-maintained list.
    """
    if hasattr(failed, "failed"):
        failed = failed.failed()
    dead = {int(h) for h in failed}
    live = [i for i in range(n_devices) if i not in dead]
    if not live:
        raise ValueError(f"no live devices left of {n_devices} "
                         f"(failed: {sorted(dead)})")
    return make_mesh(len(live), axis_names=axis_names,
                     model_parallel=model_parallel)


def _valid_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Demote axes whose mesh factor no longer divides the dim."""
    parts = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            parts.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        factor = int(np.prod([mesh.shape[a] for a in axes]))
        parts.append(ax if dim % factor == 0 else None)
    return P(*parts)


def reshard(tree: Any, specs: Any, new_mesh: Mesh) -> Tuple[Any, RemeshPlan]:
    demotions: List[str] = []

    def move(path, x, spec):
        sp = _valid_spec(spec, x.shape, new_mesh)
        if tuple(sp) != tuple(spec):
            demotions.append(jax.tree_util.keystr(path))
        return jax.device_put(x, NamedSharding(new_mesh, sp))

    out = jax.tree_util.tree_map_with_path(move, tree, specs)
    plan = RemeshPlan(old_shape=(), new_shape=tuple(new_mesh.devices.shape),
                      axis_names=tuple(new_mesh.axis_names),
                      demotions=demotions)
    return out, plan
