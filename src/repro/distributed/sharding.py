"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Models annotate params and activations with *logical* axis names; this module
maps them onto physical mesh axes for a given :class:`ParallelismConfig`.
The mapping is installed via a context manager so model code stays
mesh-agnostic (smoke tests run with no mesh at all — constraints become
no-ops).

Physical axes:  optional ``pod`` (DCN), ``data`` (DP/FSDP/SP), ``model``
(TP/EP).  See DESIGN.md §3.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelismConfig, ShapeConfig

# Logical axis names used across the model zoo.
PARAM_AXES = ("layers", "embed", "q_heads", "kv_heads", "mlp", "vocab",
              "expert", "ssm_inner", "ssm_state", "conv", "classes")
ACT_AXES = ("batch", "act_seq", "kv_seq", "act_heads", "act_kv", "act_mlp",
            "act_embed", "act_vocab", "act_expert", "act_inner")


@dataclass(frozen=True)
class ShardingRules:
    mapping: Dict[str, Any]
    enabled: bool = True
    mesh: Any = None               # jax Mesh when EP shard_map paths are live
    ep_axis: Optional[str] = None  # physical axis experts shard over
    batch_axes: Any = None         # physical axes the batch shards over

    def spec(self, *axes: Optional[str]) -> P:
        return P(*[self.mapping.get(a) if a is not None else None
                   for a in axes])


_NULL = ShardingRules(mapping={}, enabled=False)
_current: contextvars.ContextVar[ShardingRules] = contextvars.ContextVar(
    "sharding_rules", default=_NULL)


@contextlib.contextmanager
def use_rules(rules: ShardingRules):
    tok = _current.set(rules)
    try:
        yield rules
    finally:
        _current.reset(tok)


def current_rules() -> ShardingRules:
    return _current.get()


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical axes (no-op w/o rules)."""
    rules = _current.get()
    if not rules.enabled:
        return x
    return jax.lax.with_sharding_constraint(x, rules.spec(*axes))


def make_rules(model: ModelConfig, shape: ShapeConfig,
               parallel: ParallelismConfig, *,
               multi_pod: bool = False, tp_size: int = 16,
               dp_size: int = 16, mesh: Any = None) -> ShardingRules:
    """Build the logical->physical mapping for one (arch x shape) cell."""
    batch_axes: Any = ("pod", "data") if multi_pod else ("data",)
    dp_total = dp_size * (2 if multi_pod else 1)
    # pure-DP over the model axis only when the batch actually divides the
    # widened grid; otherwise fall back to TP (an idle model axis would
    # replicate 16x the per-chip work)
    pure_dp = (parallel.dp_over_model and not parallel.tp and not parallel.ep
               and shape.global_batch % (dp_total * tp_size) == 0)
    tp = parallel.tp or (parallel.dp_over_model and not pure_dp)
    if pure_dp:
        batch_axes = batch_axes + ("model",)
        dp_total *= tp_size
    hd = model.resolved_head_dim

    m: Dict[str, Any] = {}
    # ----- params -----
    m["layers"] = None
    m["embed"] = "data" if parallel.fsdp else None
    m["q_heads"] = "model" if tp else None
    kv_ok = model.n_kv_heads and (model.n_kv_heads % tp_size == 0)
    m["kv_heads"] = "model" if (tp and kv_ok) else None
    m["mlp"] = "model" if tp else None
    m["vocab"] = "model" if tp else None
    m["expert"] = "model" if parallel.ep else None
    m["ssm_inner"] = "model" if tp else None
    m["ssm_state"] = None
    m["conv"] = None
    m["classes"] = None
    # ----- activations -----
    batch_shardable = shape.global_batch % dp_total == 0 and \
        shape.global_batch >= dp_total
    m["batch"] = batch_axes if batch_shardable else None
    # SP shards activations' sequence dim only when the batch can't shard
    # (long_500k, batch=1); prefill batches (>=32) shard over data directly.
    m["act_seq"] = "data" if (parallel.sp and not batch_shardable
                              and shape.kind != "decode") else None
    if parallel.sp_ssd and shape.kind == "prefill" and not tp:
        m["act_seq"] = "model"      # sequence-parallel SSD (ssm_sp.py)
    # decode KV layout: batch over data when possible; the sequence dim of the
    # cache goes to 'model' (flash-decoding style partial-softmax, XLA
    # partitions the softmax reductions) unless kv heads already shard.
    if shape.kind == "decode":
        m["kv_seq"] = "model" if not kv_ok else None
        if shape.name == "long_500k":
            m["kv_seq"] = "data" if not batch_shardable else "model"
    else:
        m["kv_seq"] = None
    m["act_heads"] = "model" if tp else None
    m["act_kv"] = "model" if (tp and kv_ok) else None
    m["act_mlp"] = "model" if tp else None
    m["act_embed"] = None
    m["act_vocab"] = "model" if tp else None
    m["act_expert"] = "model" if parallel.ep else None
    m["act_inner"] = "model" if tp else None
    m["ssm_gather_out"] = bool(parallel.ssm_gather_out)
    return ShardingRules(
        mapping=m, mesh=mesh,
        ep_axis="model" if parallel.ep else None,
        batch_axes=m["batch"])


def data_axis_names(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)
