"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

shard_map + ``lax.ppermute``: each rank owns a contiguous stage of layers;
microbatches flow through a steady-state loop with (S + M - 1) ticks for M
microbatches over S stages.  Offered as an alternative layout for archs
whose layer count dwarfs the TP width; correctness is covered by
tests/test_distributed.py against the single-device stack.  Forward-only
(inference PP) here; training PP composes this with recomputed backward
stages — out of scope for the assigned cells (FSDP+TP covers them) and
noted in DESIGN.md.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import pvary, shard_map


def pipeline_forward(block_fn: Callable, params_stacked, x,
                     mesh: Mesh, axis: str = "pipe",
                     microbatches: int = 4):
    """Run a layer stack split into ``pipe`` stages over microbatches.

    block_fn(layer_params, x) -> x;  params_stacked leaves: (L, ...) with
    L % n_stages == 0; x: (B, ...) with B % microbatches == 0.
    """
    n_stages = mesh.shape[axis]
    L = jax.tree.leaves(params_stacked)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)

    def stage(params_local, x_local):
        # params_local: (L/S, ...) this stage's layers
        def run_stage(xm):
            def body(h, lp):
                return block_fn(lp, h), None
            out, _ = jax.lax.scan(body, xm, params_local)
            return out

        rank = jax.lax.axis_index(axis)
        B = x_local.shape[0]
        mb = B // microbatches
        bufs = x_local.reshape((microbatches, mb) + x_local.shape[1:])
        # carries become rank-varying inside the loop; mark them so
        out = pvary(jnp.zeros_like(bufs), (axis,))
        # steady-state loop: tick t processes microbatch (t - rank) at rank
        cur = pvary(
            jnp.zeros((mb,) + x_local.shape[1:], x_local.dtype), (axis,))
        n_ticks = microbatches + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(t, carry):
            cur, out = carry
            # stage 0 injects microbatch t (if in range)
            inject = jax.lax.dynamic_index_in_dim(
                bufs, jnp.clip(t, 0, microbatches - 1), 0, keepdims=False)
            cur = jnp.where(rank == 0,
                            jnp.where(t < microbatches, inject, cur), cur)
            y = run_stage(cur)
            # last stage emits microbatch (t - n_stages + 1)
            emit_idx = jnp.clip(t - n_stages + 1, 0, microbatches - 1)
            emit_ok = (rank == n_stages - 1) & (t - n_stages + 1 >= 0)
            old = jax.lax.dynamic_index_in_dim(out, emit_idx, 0,
                                               keepdims=False)
            new = jnp.where(emit_ok, y, old)
            out = jax.lax.dynamic_update_index_in_dim(out, new, emit_idx, 0)
            # rotate activations to the next stage
            cur = jax.lax.ppermute(y, axis, perm)
            return cur, out

        cur, out = jax.lax.fori_loop(0, n_ticks, tick, (cur, out))
        # only the last stage holds real outputs; broadcast them
        out = jax.lax.psum(
            jnp.where(rank == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out.reshape(x_local.shape)

    f = shard_map(
        stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P())
    return f(params_stacked, x)
