"""Fault-tolerant checkpointing: atomic, content-indexed, resumable.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json     # tree structure, shapes, dtypes, step, extras
        arrays.npz        # flattened leaves keyed by path
    <dir>/LATEST          # atomically-updated pointer

Writes go to ``step_xxx.tmp`` and are renamed into place only after fsync,
so a crash mid-write never corrupts the restore point.  At pod scale each
host writes its own param shards; this single-process implementation
gathers leaves (device_get) but keeps the same manifest format, so the
on-disk contract is scale-independent.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any,
         extras: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    dtypes = {k: str(v.dtype) for k, v in arrays.items()}
    # npz can't round-trip ml_dtypes (bfloat16 etc.) — store raw views and
    # record the true dtype in the manifest
    stored = {k: (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
              for k, v in arrays.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": dtypes,
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # atomic LATEST pointer
    ptr = os.path.join(ckpt_dir, "LATEST")
    fd, tmp_ptr = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp_ptr, ptr)
    return final


def _is_complete(path: str) -> bool:
    """A checkpoint directory is complete iff its manifest parses, its
    arrays.npz opens, and every manifest key has an array.  Crash-
    truncated or partially-pruned checkpoints fail one of these."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as data:
            files = set(data.files)
        return set(manifest["keys"]) <= files
    except (OSError, ValueError, KeyError, json.JSONDecodeError,
            zipfile.BadZipFile):
        return False


def _step_dirs(ckpt_dir: str) -> List[str]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(d for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and not d.endswith(".tmp"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *complete* checkpoint step, or None.

    The LATEST pointer is the fast path; when it is stale, missing, or
    names an incomplete directory (crash mid-write, overlapping prune)
    fall back to scanning step dirs newest-first and return the first
    that validates.
    """
    ptr = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(ptr):
        with open(ptr) as f:
            name = f.read().strip()
        path = os.path.join(ckpt_dir, name)
        if os.path.isdir(path) and _is_complete(path):
            return int(name.split("_")[1])
    for name in reversed(_step_dirs(ckpt_dir)):
        if _is_complete(os.path.join(ckpt_dir, name)):
            return int(name.split("_")[1])
    return None


def restore(ckpt_dir: str, template: Any,
            step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``template`` (shape-checked).

    With ``step=None`` the newest complete checkpoint is used; if that
    directory disappears or truncates between selection and read (prune
    racing restore), selection retries on the survivors — genuine
    template mismatches (shapes, missing keys) still raise.
    """
    if step is not None:
        return _restore_path(
            os.path.join(ckpt_dir, f"step_{step:08d}"), template)
    last_err: Optional[Exception] = None
    for _attempt in range(4):
        chosen = latest_step(ckpt_dir)
        if chosen is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
        try:
            return _restore_path(
                os.path.join(ckpt_dir, f"step_{chosen:08d}"), template)
        except (OSError, zipfile.BadZipFile, json.JSONDecodeError) as e:
            last_err = e               # dir vanished/truncated under us
    raise FileNotFoundError(
        f"no stable checkpoint in {ckpt_dir}: {last_err!r}")


def _restore_path(path: str, template: Any) -> Tuple[Any, Dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t = _flatten(template)
    missing = set(flat_t) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keyed = _flatten(template)
    order = list(keyed.keys())
    # rebuild in template leaf order
    new_leaves = []
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    import ml_dtypes
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        dtype = getattr(leaf, "dtype", arr.dtype)
        new_leaves.append(jax.numpy.asarray(arr, dtype=dtype))
    return treedef.unflatten(new_leaves), manifest


def prune(ckpt_dir: str, keep: int = 3) -> List[str]:
    """Keep the newest ``keep`` checkpoints, drop the rest."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    removed = []
    for d in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, d))
        removed.append(d)
    return removed
