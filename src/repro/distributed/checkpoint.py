"""Fault-tolerant checkpointing: atomic, content-indexed, resumable.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json     # tree structure, shapes, dtypes, step, extras
        arrays.npz        # flattened leaves keyed by path
    <dir>/LATEST          # atomically-updated pointer

Writes go to ``step_xxx.tmp`` and are renamed into place only after fsync,
so a crash mid-write never corrupts the restore point.  At pod scale each
host writes its own param shards; this single-process implementation
gathers leaves (device_get) but keeps the same manifest format, so the
on-disk contract is scale-independent.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any,
         extras: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    dtypes = {k: str(v.dtype) for k, v in arrays.items()}
    # npz can't round-trip ml_dtypes (bfloat16 etc.) — store raw views and
    # record the true dtype in the manifest
    stored = {k: (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
              for k, v in arrays.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": dtypes,
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # atomic LATEST pointer
    ptr = os.path.join(ckpt_dir, "LATEST")
    fd, tmp_ptr = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp_ptr, ptr)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template: Any,
            step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``template`` (shape-checked)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t = _flatten(template)
    missing = set(flat_t) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keyed = _flatten(template)
    order = list(keyed.keys())
    # rebuild in template leaf order
    new_leaves = []
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    import ml_dtypes
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        dtype = getattr(leaf, "dtype", arr.dtype)
        new_leaves.append(jax.numpy.asarray(arr, dtype=dtype))
    return treedef.unflatten(new_leaves), manifest


def prune(ckpt_dir: str, keep: int = 3) -> List[str]:
    """Keep the newest ``keep`` checkpoints, drop the rest."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    removed = []
    for d in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, d))
        removed.append(d)
    return removed
