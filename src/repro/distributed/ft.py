"""Fault tolerance: heartbeats, failure detection, restart, stragglers.

``ResilientTrainer`` wraps a train step with the full production loop:

* periodic atomic checkpoints (distributed/checkpoint.py);
* a heartbeat registry — hosts that miss ``dead_after`` heartbeats are
  declared failed; the trainer restores the latest checkpoint and resumes
  (optionally on a re-sized mesh via distributed/elastic.py);
* straggler mitigation for the *data* path: if a batch misses its
  deadline, the ODS service substitutes cached unseen samples instead of
  stalling the step (the paper's opportunistic sampling doubles as
  straggler relief — DESIGN.md §3);
* failure injection hooks for tests/examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.distributed import checkpoint as ckpt


@dataclass
class HeartbeatRegistry:
    dead_after_s: float = 10.0
    last_beat: Dict[int, float] = field(default_factory=dict)

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self.last_beat[host] = now if now is not None else time.monotonic()

    def failed_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.monotonic()
        return [h for h, t in self.last_beat.items()
                if now - t > self.dead_after_s]


@dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    dead_after_s: float = 10.0
    batch_deadline_s: Optional[float] = None   # straggler cutoff
    max_restarts: int = 10


class ResilientTrainer:
    """step_fn(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def __init__(self, step_fn: Callable, params, opt_state,
                 cfg: FTConfig,
                 batch_source: Callable[[], Any],
                 straggler_substitute: Optional[Callable[[], Any]] = None,
                 failure_injector: Optional[Callable[[int], bool]] = None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.cfg = cfg
        self.batch_source = batch_source
        self.straggler_substitute = straggler_substitute
        self.failure_injector = failure_injector
        self.heartbeats = HeartbeatRegistry(cfg.dead_after_s)
        self.step = 0
        self.restarts = 0
        self.straggler_substitutions = 0
        self.history: List[Dict] = []

    # ------------------------------------------------------------------
    def _checkpoint(self) -> None:
        ckpt.save(self.cfg.ckpt_dir, self.step,
                  {"params": self.params, "opt": self.opt_state},
                  extras={"restarts": self.restarts})
        ckpt.prune(self.cfg.ckpt_dir, self.cfg.keep)

    def _restore(self) -> None:
        tree, manifest = ckpt.restore(
            self.cfg.ckpt_dir, {"params": self.params,
                                "opt": self.opt_state})
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = manifest["step"]

    # ------------------------------------------------------------------
    def _get_batch(self):
        if self.cfg.batch_deadline_s is None or \
                self.straggler_substitute is None:
            return self.batch_source()
        t0 = time.monotonic()
        batch = self.batch_source()
        if time.monotonic() - t0 > self.cfg.batch_deadline_s:
            self.straggler_substitutions += 1
            return self.straggler_substitute()
        return batch

    def run(self, n_steps: int) -> List[Dict]:
        if ckpt.latest_step(self.cfg.ckpt_dir) is not None:
            self._restore()            # resume an interrupted run
        while self.step < n_steps:
            if self.failure_injector and self.failure_injector(self.step):
                # simulated node failure: lose in-memory state, restart
                if self.restarts >= self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted")
                self.restarts += 1
                self._restore()
                continue
            batch = self._get_batch()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            self.heartbeats.beat(0)
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = self.step
            self.history.append(rec)
            if self.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
        self._checkpoint()
        return self.history
