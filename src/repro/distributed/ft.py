"""Fault tolerance: heartbeats, failure detection, restart, stragglers.

``ResilientTrainer`` wraps a train step with the full production loop:

* periodic atomic checkpoints (distributed/checkpoint.py);
* a heartbeat registry — hosts that miss ``dead_after`` heartbeats are
  declared failed; the trainer restores the latest checkpoint and resumes
  (optionally on a re-sized mesh via distributed/elastic.py);
* straggler mitigation for the *data* path: if a batch misses its
  deadline, the ODS service substitutes cached unseen samples instead of
  stalling the step (the paper's opportunistic sampling doubles as
  straggler relief — DESIGN.md §3);
* failure injection hooks for tests/examples.

All timing runs on an injected ``Clock`` (default
:class:`~repro.workload.clock.RealClock`), so heartbeat expiry and
batch deadlines are testable under ``VirtualClock`` like the rest of
the stack.  ``HeartbeatRegistry`` is now a thin host-flavoured view of
the generalized :class:`~repro.faults.liveness.LivenessRegistry` shared
with the sharded cache client.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.distributed import checkpoint as ckpt
from repro.faults.liveness import LivenessRegistry


class HeartbeatRegistry(LivenessRegistry):
    """Host-liveness view kept for API compatibility: ``beat(host)`` /
    ``failed_hosts()`` over the generalized registry."""

    def failed_hosts(self, now: Optional[float] = None) -> List[int]:
        return self.failed(now)


@dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    dead_after_s: float = 10.0
    batch_deadline_s: Optional[float] = None   # straggler cutoff
    max_restarts: int = 10


class ResilientTrainer:
    """step_fn(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def __init__(self, step_fn: Callable, params, opt_state,
                 cfg: FTConfig,
                 batch_source: Callable[[], Any],
                 straggler_substitute: Optional[Callable[[], Any]] = None,
                 failure_injector: Optional[Callable[[int], bool]] = None,
                 clock: Optional[Any] = None):
        if clock is None:
            from repro.workload.clock import RealClock
            clock = RealClock()
        self.clock = clock
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        # keep the initial state so a missing/corrupt checkpoint restarts
        # from step 0 instead of crashing the whole job
        self._init_params = jax.tree_util.tree_map(lambda x: x, params)
        self._init_opt = jax.tree_util.tree_map(lambda x: x, opt_state)
        self.cfg = cfg
        self.batch_source = batch_source
        self.straggler_substitute = straggler_substitute
        self.failure_injector = failure_injector
        self.heartbeats = HeartbeatRegistry(cfg.dead_after_s, clock=clock)
        self.step = 0
        self.restarts = 0
        self.straggler_substitutions = 0
        self.history: List[Dict] = []

    # ------------------------------------------------------------------
    def _checkpoint(self) -> None:
        ckpt.save(self.cfg.ckpt_dir, self.step,
                  {"params": self.params, "opt": self.opt_state},
                  extras={"restarts": self.restarts})
        ckpt.prune(self.cfg.ckpt_dir, self.cfg.keep)

    def _restore(self) -> None:
        """Restore the newest complete checkpoint; with none usable,
        restart from the initial state at step 0 rather than crash."""
        try:
            tree, manifest = ckpt.restore(
                self.cfg.ckpt_dir, {"params": self.params,
                                    "opt": self.opt_state})
        except (FileNotFoundError, ValueError, KeyError, OSError):
            self.params = self._init_params
            self.opt_state = self._init_opt
            self.step = 0
            return
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = manifest["step"]

    # ------------------------------------------------------------------
    def _get_batch(self):
        if self.cfg.batch_deadline_s is None or \
                self.straggler_substitute is None:
            return self.batch_source()
        t0 = self.clock.now()
        batch = self.batch_source()
        if self.clock.now() - t0 > self.cfg.batch_deadline_s:
            self.straggler_substitutions += 1
            return self.straggler_substitute()
        return batch

    def _restart(self) -> None:
        if self.restarts >= self.cfg.max_restarts:
            raise RuntimeError("restart budget exhausted")
        self.restarts += 1
        self._restore()

    def run(self, n_steps: int) -> List[Dict]:
        if ckpt.latest_step(self.cfg.ckpt_dir) is not None:
            self._restore()            # resume an interrupted run
        while self.step < n_steps:
            if self.failure_injector and self.failure_injector(self.step):
                # simulated node failure: lose in-memory state, restart
                self._restart()
                continue
            failed = self.heartbeats.failed_hosts()
            if failed:
                # a host missed its heartbeat window (or was marked dead
                # by a fault injector): restore and bring it back in
                self._restart()
                for h in failed:
                    self.heartbeats.mark_alive(h)
                continue
            batch = self._get_batch()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            self.heartbeats.beat(0)
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = self.step
            self.history.append(rec)
            if self.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
        self._checkpoint()
        return self.history
