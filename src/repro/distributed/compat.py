"""JAX API compatibility layer.

The source tree targets the modern top-level spellings (``jax.shard_map``,
``jax.set_mesh``, both stabilized after 0.4.x); the pinned toolchain in
this container ships jax 0.4.37 where they live under
``jax.experimental.shard_map`` and the ``Mesh`` context manager.  Every
mesh/shard_map call site imports from here so the same code runs on both.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:                       # jax < 0.4.x top-level export
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, **kw):
        # The callers are written for the modern API where replication is
        # marked explicitly with ``pvary``; the 0.4.x rep-checker cannot
        # see those marks (``pvary`` below is an identity there), so turn
        # static rep inference off and let the numeric tests be the check.
        kw.setdefault("check_rep", False)
        return _shard_map(f, **kw)

try:
    pvary = jax.lax.pvary
except AttributeError:
    def pvary(x, axis_name):
        """Devices-vary marker only exists post-0.4.x; without the
        varying-manual-axes type system it is a no-op."""
        del axis_name
        return x

try:
    set_mesh = jax.set_mesh
except AttributeError:
    def set_mesh(mesh):
        """On 0.4.x the Mesh object itself is the resource-env context
        manager that lets bare PartitionSpecs resolve inside jit."""
        return mesh
