"""Shard transports: how protocol requests reach the shard fleet.

* :class:`SimTransport` — shards live as plain objects in the calling
  process and ``call`` is a direct method invocation on the caller's
  thread.  Zero concurrency of its own, which is the point: under the
  :class:`~repro.workload.clock.VirtualClock` turn discipline every
  shard call executes synchronously inside the caller's turn, so
  sharded runs are byte-for-byte deterministic.
* :class:`ProcessTransport` — one OS process per shard (``spawn``
  context: the parent runs worker threads, and forking a threaded
  process is undefined behavior).  Control messages (pickled
  Request/Response) travel over one duplex pipe per shard, guarded by a
  per-shard lock; bulk payloads travel as
  :class:`~repro.cache.codecs.PayloadRef` files through the exchange
  directory (memmap + unlink — the page cache, not the pipe, carries
  the bytes).

Both expose the same three members (``call``, ``close``,
``wants_refs``), so the client cannot tell them apart.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
from typing import List, Sequence

from repro.service import proto
from repro.service.shard import CacheShard, ShardConfig

TRANSPORTS = ("sim", "process")


class ShardDownError(RuntimeError):
    """A call targeted a shard that is dead (killed by fault injection
    or lost mid-call).  The client catches this and fails the shard's
    key range over to storage."""


class SimTransport:
    """In-process shards; deterministic and free."""

    name = "sim"
    #: payloads stay live Python objects — no exchange-dir indirection
    wants_refs = False

    def __init__(self, configs: Sequence[ShardConfig]):
        self._configs = list(configs)
        self.shards: List[CacheShard] = []
        try:
            for cfg in configs:
                self.shards.append(CacheShard(cfg))
        except BaseException:
            self.close()
            raise

    def call(self, shard_id: int, req: proto.Request) -> proto.Response:
        shard = self.shards[shard_id]
        if shard is None:
            raise ShardDownError(f"shard {shard_id} is down")
        return shard.handle(req)

    def kill(self, shard_id: int) -> None:
        """Simulate shard death: drop the object (its spill files go
        with it, like a crashed process's would on restart)."""
        shard = self.shards[shard_id]
        if shard is not None:
            shard.close()
            self.shards[shard_id] = None

    def restart(self, shard_id: int) -> None:
        """Cold-restart a killed shard from its original config."""
        if self.shards[shard_id] is not None:
            return
        self.shards[shard_id] = CacheShard(self._configs[shard_id])

    def close(self) -> None:
        for shard in self.shards:
            if shard is not None:
                shard.close()


def _shard_main(cfg: ShardConfig, conn) -> None:
    """Child-process entry: build the shard, report readiness, then
    serve the pipe until CLOSE/EOF.  The cache is torn down on every
    exit path so a dying shard leaks no spill files."""
    try:
        shard = CacheShard(cfg)
    except BaseException as e:
        try:
            conn.send(proto.Response(
                False, error=f"{type(e).__name__}: {e}"))
        finally:
            conn.close()
        return
    conn.send(proto.Response(True, value="ready"))
    try:
        while True:
            try:
                req = conn.recv()
            except (EOFError, OSError):
                break
            resp = shard.handle(req)
            try:
                conn.send(resp)
            except (BrokenPipeError, OSError):
                break
            if req.op == proto.OP_CLOSE:
                break
    finally:
        shard.close()
        conn.close()


class ProcessTransport:
    """One spawned OS process per shard, request/response over a pipe.

    Thread-safe per shard: a lock serializes each pipe (concurrent
    callers to *different* shards proceed in parallel — that is the
    transport's entire performance story).  Construction blocks on a
    readiness handshake so a shard that fails to build (bad spill dir,
    unpicklable config) surfaces as an exception here, not a hang on
    first call; a partially built fleet is torn down before the raise.
    """

    name = "process"
    wants_refs = True

    def __init__(self, configs: Sequence[ShardConfig],
                 start_method: str = "spawn",
                 start_timeout: float = 120.0):
        ctx = mp.get_context(start_method)
        self._ctx = ctx
        self._configs = list(configs)
        self._start_timeout = start_timeout
        self._procs: list = []
        self._conns: list = []
        self._locks: List[threading.Lock] = []
        self._dead: set = set()
        self._closed = False
        try:
            for cfg in configs:
                parent, child = ctx.Pipe()
                proc = ctx.Process(
                    target=_shard_main, args=(cfg, child),
                    name=f"seneca-shard-{cfg.shard_id}", daemon=True)
                proc.start()
                child.close()
                self._procs.append(proc)
                self._conns.append(parent)
                self._locks.append(threading.Lock())
            for i, conn in enumerate(self._conns):
                if not conn.poll(start_timeout):
                    raise RuntimeError(
                        f"shard {i} not ready within {start_timeout}s")
                resp = conn.recv()
                if not resp.ok:
                    raise RuntimeError(
                        f"shard {i} failed to start: {resp.error}")
        except BaseException:
            self.close()
            raise

    def call(self, shard_id: int, req: proto.Request) -> proto.Response:
        if self._closed:
            raise RuntimeError("transport is closed")
        if shard_id in self._dead:
            raise ShardDownError(f"shard {shard_id} is down")
        with self._locks[shard_id]:
            conn = self._conns[shard_id]
            try:
                conn.send(req)
                return conn.recv()
            except (BrokenPipeError, EOFError, OSError) as e:
                # the shard died under us: mark it so callers fail over
                # instead of hammering a broken pipe
                self._dead.add(shard_id)
                raise ShardDownError(
                    f"shard {shard_id} lost mid-call: {e!r}") from e

    def kill(self, shard_id: int) -> None:
        """Hard-kill the shard process (fault injection)."""
        if shard_id in self._dead:
            return
        self._dead.add(shard_id)
        with self._locks[shard_id]:
            proc = self._procs[shard_id]
            proc.terminate()
            proc.join(timeout=10.0)
            try:
                self._conns[shard_id].close()
            except OSError:
                pass

    def restart(self, shard_id: int) -> None:
        """Spawn a fresh shard process from the original config (cold
        cache) and block on its readiness handshake."""
        if shard_id not in self._dead:
            return
        cfg = self._configs[shard_id]
        parent, child = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=_shard_main, args=(cfg, child),
            name=f"seneca-shard-{cfg.shard_id}", daemon=True)
        proc.start()
        child.close()
        if not parent.poll(self._start_timeout):
            proc.terminate()
            raise RuntimeError(
                f"restarted shard {shard_id} not ready within "
                f"{self._start_timeout}s")
        resp = parent.recv()
        if not resp.ok:
            raise RuntimeError(
                f"shard {shard_id} failed to restart: {resp.error}")
        with self._locks[shard_id]:
            self._procs[shard_id] = proc
            self._conns[shard_id] = parent
        self._dead.discard(shard_id)

    def close(self) -> None:
        """Idempotent orderly shutdown: CLOSE every shard (so spill
        files are cleared by the owning process), then join, escalating
        to terminate for stragglers."""
        if self._closed:
            return
        self._closed = True
        for i, conn in enumerate(self._conns):
            if i in self._dead:
                continue
            with self._locks[i]:
                try:
                    conn.send(proto.Request(proto.OP_CLOSE))
                    if conn.poll(5.0):
                        conn.recv()
                except (BrokenPipeError, OSError):
                    pass
                try:
                    conn.close()
                except OSError:
                    pass
        for proc in self._procs:
            proc.join(timeout=10.0)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)


def make_transport(name: str, configs: Sequence[ShardConfig], **kwargs):
    if name == "sim":
        return SimTransport(configs)
    if name == "process":
        return ProcessTransport(configs, **kwargs)
    raise ValueError(f"unknown shard transport {name!r}; "
                     f"expected one of {TRANSPORTS}")
