"""Shard transports: how protocol requests reach the shard fleet.

* :class:`SimTransport` — shards live as plain objects in the calling
  process and ``call`` is a direct method invocation on the caller's
  thread.  Zero concurrency of its own, which is the point: under the
  :class:`~repro.workload.clock.VirtualClock` turn discipline every
  shard call executes synchronously inside the caller's turn, so
  sharded runs are byte-for-byte deterministic.
* :class:`ProcessTransport` — one OS process per shard (``spawn``
  context: the parent runs worker threads, and forking a threaded
  process is undefined behavior).  Control messages (pickled
  Request/Response) travel over one duplex pipe per shard, guarded by a
  per-shard lock; bulk payloads travel as
  :class:`~repro.cache.codecs.PayloadRef` files through the exchange
  directory (memmap + unlink — the page cache, not the pipe, carries
  the bytes).

Both expose the same three members (``call``, ``close``,
``wants_refs``), so the client cannot tell them apart.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
from typing import List, Sequence

from repro.service import proto
from repro.service.shard import CacheShard, ShardConfig

TRANSPORTS = ("sim", "process")


class SimTransport:
    """In-process shards; deterministic and free."""

    name = "sim"
    #: payloads stay live Python objects — no exchange-dir indirection
    wants_refs = False

    def __init__(self, configs: Sequence[ShardConfig]):
        self.shards: List[CacheShard] = []
        try:
            for cfg in configs:
                self.shards.append(CacheShard(cfg))
        except BaseException:
            self.close()
            raise

    def call(self, shard_id: int, req: proto.Request) -> proto.Response:
        return self.shards[shard_id].handle(req)

    def close(self) -> None:
        for shard in self.shards:
            shard.close()


def _shard_main(cfg: ShardConfig, conn) -> None:
    """Child-process entry: build the shard, report readiness, then
    serve the pipe until CLOSE/EOF.  The cache is torn down on every
    exit path so a dying shard leaks no spill files."""
    try:
        shard = CacheShard(cfg)
    except BaseException as e:
        try:
            conn.send(proto.Response(
                False, error=f"{type(e).__name__}: {e}"))
        finally:
            conn.close()
        return
    conn.send(proto.Response(True, value="ready"))
    try:
        while True:
            try:
                req = conn.recv()
            except (EOFError, OSError):
                break
            resp = shard.handle(req)
            try:
                conn.send(resp)
            except (BrokenPipeError, OSError):
                break
            if req.op == proto.OP_CLOSE:
                break
    finally:
        shard.close()
        conn.close()


class ProcessTransport:
    """One spawned OS process per shard, request/response over a pipe.

    Thread-safe per shard: a lock serializes each pipe (concurrent
    callers to *different* shards proceed in parallel — that is the
    transport's entire performance story).  Construction blocks on a
    readiness handshake so a shard that fails to build (bad spill dir,
    unpicklable config) surfaces as an exception here, not a hang on
    first call; a partially built fleet is torn down before the raise.
    """

    name = "process"
    wants_refs = True

    def __init__(self, configs: Sequence[ShardConfig],
                 start_method: str = "spawn",
                 start_timeout: float = 120.0):
        ctx = mp.get_context(start_method)
        self._procs: list = []
        self._conns: list = []
        self._locks: List[threading.Lock] = []
        self._closed = False
        try:
            for cfg in configs:
                parent, child = ctx.Pipe()
                proc = ctx.Process(
                    target=_shard_main, args=(cfg, child),
                    name=f"seneca-shard-{cfg.shard_id}", daemon=True)
                proc.start()
                child.close()
                self._procs.append(proc)
                self._conns.append(parent)
                self._locks.append(threading.Lock())
            for i, conn in enumerate(self._conns):
                if not conn.poll(start_timeout):
                    raise RuntimeError(
                        f"shard {i} not ready within {start_timeout}s")
                resp = conn.recv()
                if not resp.ok:
                    raise RuntimeError(
                        f"shard {i} failed to start: {resp.error}")
        except BaseException:
            self.close()
            raise

    def call(self, shard_id: int, req: proto.Request) -> proto.Response:
        if self._closed:
            raise RuntimeError("transport is closed")
        with self._locks[shard_id]:
            conn = self._conns[shard_id]
            conn.send(req)
            return conn.recv()

    def close(self) -> None:
        """Idempotent orderly shutdown: CLOSE every shard (so spill
        files are cleared by the owning process), then join, escalating
        to terminate for stragglers."""
        if self._closed:
            return
        self._closed = True
        for i, conn in enumerate(self._conns):
            with self._locks[i]:
                try:
                    conn.send(proto.Request(proto.OP_CLOSE))
                    if conn.poll(5.0):
                        conn.recv()
                except (BrokenPipeError, OSError):
                    pass
                try:
                    conn.close()
                except OSError:
                    pass
        for proc in self._procs:
            proc.join(timeout=10.0)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)


def make_transport(name: str, configs: Sequence[ShardConfig], **kwargs):
    if name == "sim":
        return SimTransport(configs)
    if name == "process":
        return ProcessTransport(configs, **kwargs)
    raise ValueError(f"unknown shard transport {name!r}; "
                     f"expected one of {TRANSPORTS}")
