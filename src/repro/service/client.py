"""The sharded data plane's client: a drop-in ``TieredCache``.

:class:`ShardedCache` implements the full cache surface
``api/server.py`` consumes (lookup/insert/evict/resize/residency/stats)
by routing every key through a :class:`~repro.service.router.ShardRouter`
to one of N :class:`~repro.service.shard.CacheShard` instances behind a
transport.  ``SenecaService`` therefore works unchanged over 1 process
or N — ``Session`` / ``DSIPipeline`` / ``WorkloadRunner`` cannot tell
the difference.

Cross-shard bookkeeping lives here:

* **evictions** — every shard response piggybacks the keys its tier
  chains dropped; the client accumulates them so ``take_evicted`` /
  ``has_pending_evicted`` behave exactly like the local cache's.
* **version** — the composite residency version is the sum of the
  latest per-shard versions (each shard's counter is monotone, shards
  are disjoint, so the sum is monotone and changes iff some shard's
  residency may have).
* **residency/status gathers** — each shard reports its full array
  (nonzero only on the keys it owns) and the client merges them with
  :func:`repro.core.ods.merge_residency`.
"""
from __future__ import annotations

import itertools
import os
import shutil
import tempfile
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.cache.codecs import PayloadRef, receive_payload, ship_payload
from repro.cache.store import FORMS
from repro.core.ods import merge_residency
from repro.faults.liveness import LivenessRegistry
from repro.service import proto
from repro.service.router import ShardRouter
from repro.service.shard import ShardConfig
from repro.service.transport import ShardDownError, make_transport


class ShardedCache:
    """N-shard cache behind the ``TieredCache`` surface.

    Capacity (and any spill budget) divides evenly across shards; each
    shard either reuses the pinned ``split`` or — with
    ``solve_per_shard`` and the profiles provided — runs its own
    form×tier MDP solve over its 1/N view
    (:func:`repro.core.mdp.optimize_shard`).
    """

    def __init__(self, capacity_bytes: int,
                 split: Optional[Tuple[float, float, float]],
                 evict_policies: Optional[Dict[str, str]] = None,
                 spill_bytes: int = 0,
                 spill_dir: Optional[str] = None,
                 spill_split: Optional[Tuple[float, float, float]] = None,
                 hbm_bytes: int = 0,
                 hbm_split: Optional[Tuple[float, float, float]] = None,
                 *,
                 shards: int = 1,
                 transport: str = "sim",
                 vnodes: int = 64,
                 seed: int = 0,
                 admission: Any = None,
                 hardware: Any = None,
                 dataset_profile: Any = None,
                 job: Any = None,
                 partition_step: float = 0.01,
                 solve_per_shard: bool = False,
                 dataset: Any = None,
                 storage_bandwidth: Optional[float] = None,
                 start_method: str = "spawn"):
        n = int(shards)
        if n < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.capacity = int(capacity_bytes)
        self.n_shards = n
        self.transport_name = transport
        self.router = ShardRouter(n, vnodes=vnodes, seed=seed)
        self._lock = threading.Lock()
        self._pending: List[int] = []
        self._shard_versions = [0] * n
        self._seq = itertools.count()
        self._closed = False
        #: shard liveness: a shard explicitly marked dead (fault
        #: injection, broken transport) has its key range failed over to
        #: storage until restart_shard brings it back
        self.liveness = LivenessRegistry()
        self.failovers = 0           # per-op fallbacks taken on dead shards
        self._generation = 0         # bumps on kill/restart: residency epoch
        solve_per_shard = (solve_per_shard and hardware is not None
                           and dataset_profile is not None)
        if split is None and not solve_per_shard:
            raise ValueError("need a split or profiles to solve one")

        per_cap = self.capacity // n
        per_spill = int(spill_bytes) // n if spill_dir else 0
        has_spill = spill_dir is not None and per_spill > 0
        self.spill_bytes = per_spill * n if has_spill else 0
        self.spill_dir = spill_dir if has_spill else None
        per_hbm = int(hbm_bytes) // n
        self.hbm_bytes = per_hbm * n
        self._xchg = (tempfile.mkdtemp(prefix="seneca-xchg-")
                      if transport == "process" else None)
        configs = [ShardConfig(
            shard_id=i, n_shards=n, cache_bytes=per_cap,
            split=None if solve_per_shard else tuple(split),
            evict_policies=(dict(evict_policies)
                            if evict_policies else None),
            admission=admission,
            spill_dir=(os.path.join(spill_dir, f"shard-{i}")
                       if has_spill else None),
            spill_bytes=per_spill if has_spill else 0,
            spill_split=(tuple(spill_split) if spill_split is not None
                         else None),
            hbm_bytes=per_hbm,
            hbm_split=(tuple(hbm_split) if hbm_split is not None
                       else None),
            hardware=hardware, dataset_profile=dataset_profile, job=job,
            partition_step=partition_step,
            dataset=dataset,
            storage_bandwidth=(storage_bandwidth / n
                               if storage_bandwidth else None),
            seed=seed + 7919 * i,
            exchange_dir=self._xchg,
        ) for i in range(n)]
        kwargs = {"start_method": start_method} \
            if transport == "process" else {}
        try:
            self.transport = make_transport(transport, configs, **kwargs)
            hello = [self._call(i, proto.OP_PING) for i in range(n)]
        except BaseException:
            self._cleanup_dirs()
            raise
        self._caps = {form: sum(h["caps"][form] for h in hello)
                      for form in FORMS}
        self.split = tuple(hello[0]["split"])
        self.spill_split = (tuple(spill_split)
                            if spill_split is not None else None)
        self.hbm_split = (tuple(hbm_split)
                          if hbm_split is not None else None)
        #: per-shard MDP labels (None entries when the split was pinned)
        self.shard_partitions = [h["partition"] for h in hello]

    # -- plumbing -------------------------------------------------------
    def _call(self, shard_id: int, op: str, *args) -> Any:
        resp = self.transport.call(shard_id, proto.Request(op, args))
        self.liveness.beat(shard_id)
        with self._lock:
            self._shard_versions[shard_id] = max(
                self._shard_versions[shard_id], resp.version)
            if resp.evicted:
                self._pending.extend(resp.evicted)
        if not resp.ok:
            raise RuntimeError(
                f"shard {shard_id} {op} failed: {resp.error}")
        return resp.value

    def _call_failover(self, shard_id: int, op: str, fallback: Any,
                       *args) -> Any:
        """Per-op degradation: a dead shard's ops return ``fallback``
        (miss / drop / zeros) instead of raising — its key range is
        effectively served by storage until the shard restarts."""
        if self.liveness.is_dead(shard_id):
            with self._lock:
                self.failovers += 1
            return fallback
        try:
            return self._call(shard_id, op, *args)
        except ShardDownError:
            self.mark_shard_down(shard_id)
            with self._lock:
                self.failovers += 1
            return fallback

    # -- shard lifecycle ------------------------------------------------
    def mark_shard_down(self, shard_id: int) -> None:
        """Record a shard as dead (detected broken transport or told by
        fault injection); bumps the residency generation so the sampler
        layer rebuilds its view of what is cached."""
        self.liveness.mark_dead(shard_id)
        with self._lock:
            self._generation += 1

    def kill_shard(self, shard_id: int) -> None:
        """Kill a shard outright (fault injection): tear down its
        process/object through the transport, then fail its range over."""
        kill = getattr(self.transport, "kill", None)
        if kill is not None:
            kill(shard_id)
        self.mark_shard_down(shard_id)

    def restart_shard(self, shard_id: int) -> None:
        """Cold-restart a dead shard and re-expand the ring onto it.
        The new shard's version counter starts over, so the old high
        count is dropped (not max-merged) or its early inserts would be
        invisible to the version-gated residency rebuild."""
        restart = getattr(self.transport, "restart", None)
        if restart is None:
            raise RuntimeError(
                f"transport {self.transport_name!r} cannot restart shards")
        restart(shard_id)
        with self._lock:
            self._shard_versions[shard_id] = 0
            self._generation += 1
        self.liveness.mark_alive(shard_id)

    def _shard_of(self, key: int) -> int:
        return self.router.shard_of(int(key))

    def _ship(self, form: str, value: Any) -> Any:
        """Outbound payload: file + ref over the process transport,
        pass-through over sim."""
        if not getattr(self.transport, "wants_refs", False) \
                or value is None:
            return value
        if not isinstance(value, (bytes, np.ndarray)):
            # device-resident arrays (HBM tier) cross processes as host
            # copies; the receiving shard re-device_puts on admission
            value = np.asarray(value)
        path = os.path.join(
            self._xchg, f"c{os.getpid()}-{next(self._seq)}.bin")
        return ship_payload(form, value, path)

    @staticmethod
    def _recv(value: Any) -> Any:
        return (receive_payload(value)
                if isinstance(value, PayloadRef) else value)

    # -- the TieredCache surface ---------------------------------------
    @property
    def version(self) -> int:
        # the generation term makes kill/restart bump the composite even
        # though a cold shard's own counter restarts at zero
        with self._lock:
            return (sum(self._shard_versions)
                    + (1 << 32) * self._generation)

    @property
    def has_spill(self) -> bool:
        return self.spill_dir is not None

    @property
    def has_hbm(self) -> bool:
        return self.hbm_bytes > 0

    def lookup(self, key: int) -> Tuple[Optional[str], Any]:
        form, value, _tier = self.lookup_tiered(key)
        return form, value

    def lookup_tiered(self, key: int
                      ) -> Tuple[Optional[str], Any, Optional[str]]:
        form, value, tier = self._call_failover(
            self._shard_of(key), proto.OP_LOOKUP, (None, None, None),
            int(key))
        return form, self._recv(value), tier

    def insert(self, key: int, form: str, value: Any,
               nbytes: int) -> bool:
        return self._call_failover(
            self._shard_of(key), proto.OP_INSERT, False,
            int(key), form, self._ship(form, value), int(nbytes), False)

    def insert_gated(self, key: int, form: str, value: Any, nbytes: int,
                     policy=None) -> bool:
        """The capacity vote runs shard-side with the shard's configured
        admission policy (``policy`` is accepted for signature parity
        but the shard's instance decides — it is the one that can be
        atomic with the put)."""
        return self._call_failover(
            self._shard_of(key), proto.OP_INSERT, False,
            int(key), form, self._ship(form, value), int(nbytes), True)

    def insert_batch_gated(self, form: str, entries,
                           policy=None) -> List[bool]:
        entries = list(entries)
        out = [False] * len(entries)
        if not entries:
            return out
        groups = self.router.group([int(k) for k, _v, _nb in entries])
        for sid in sorted(groups):
            idxs = groups[sid]
            payload = [(int(entries[i][0]),
                        self._ship(form, entries[i][1]),
                        int(entries[i][2])) for i in idxs]
            res = self._call_failover(sid, proto.OP_INSERT_BATCH,
                                      [False] * len(idxs), form, payload)
            for i, ok in zip(idxs, res):
                out[int(i)] = bool(ok)
        return out

    def evict(self, key: int, form: str) -> bool:
        return self._call_failover(self._shard_of(key), proto.OP_EVICT,
                                   False, int(key), form)

    def form_of(self, key: int) -> Optional[str]:
        return self._call_failover(self._shard_of(key), proto.OP_FORM_OF,
                                   None, int(key))

    def contains(self, form: str, key: int) -> bool:
        return self.contains_many(form, [key])[0]

    def contains_many(self, form: str, keys) -> List[bool]:
        keys = [int(k) for k in keys]
        out = [False] * len(keys)
        for sid, idxs in self.router.group(keys).items():
            res = self._call_failover(sid, proto.OP_CONTAINS,
                                      [False] * len(idxs), form,
                                      [keys[int(i)] for i in idxs])
            for i, ok in zip(idxs, res):
                out[int(i)] = bool(ok)
        return out

    def serving_forms(self, keys) -> List[Optional[str]]:
        keys = [int(k) for k in keys]
        out: List[Optional[str]] = [None] * len(keys)
        for sid, idxs in self.router.group(keys).items():
            res = self._call_failover(sid, proto.OP_SERVING_FORMS,
                                      [None] * len(idxs),
                                      [keys[int(i)] for i in idxs])
            for i, form in zip(idxs, res):
                out[int(i)] = form
        return out

    def total_capacity(self, form: str) -> int:
        return self._caps[form]

    def chain_free_bytes(self, form: str) -> int:
        return sum(self._call_failover(i, proto.OP_FREE_BYTES, 0, form)
                   for i in range(self.n_shards))

    def take_evicted(self) -> List[int]:
        with self._lock:
            out = self._pending
            self._pending = []
            return out

    def has_pending_evicted(self) -> bool:
        with self._lock:
            return bool(self._pending)

    def resize(self, split: Tuple[float, float, float],
               spill_split: Optional[Tuple[float, float, float]] = None,
               hbm_split: Optional[Tuple[float, float, float]] = None
               ) -> Dict[str, List[int]]:
        """Broadcast the new split to every shard; merge the per-shard
        evicted-key maps (disjoint keys — a plain extend)."""
        merged: Dict[str, List[int]] = {}
        for sid in range(self.n_shards):
            ev = self._call_failover(
                sid, proto.OP_RESIZE, {}, tuple(split),
                tuple(spill_split) if spill_split else None,
                tuple(hbm_split) if hbm_split else None)
            for form, keys in ev.items():
                if keys:
                    merged.setdefault(form, []).extend(keys)
        self.split = tuple(float(x) for x in split)
        if spill_split is not None:
            self.spill_split = tuple(float(y) for y in spill_split)
        if hbm_split is not None:
            self.hbm_split = tuple(float(z) for z in hbm_split)
        return merged

    def set_form_costs(self, costs: Dict[str, float]) -> None:
        for sid in range(self.n_shards):
            self._call_failover(sid, proto.OP_SET_COSTS, None,
                                dict(costs))

    def status_array(self, n: int) -> np.ndarray:
        # a dead shard's keys report 0 (IN_STORAGE) — exactly the
        # failed-over truth: its range is served by storage
        return merge_residency(
            [self._call_failover(i, proto.OP_STATUS,
                                 np.zeros(int(n), np.uint8), int(n))
             for i in range(self.n_shards)])

    def residency_array(self, n: int) -> np.ndarray:
        return merge_residency(
            [self._call_failover(i, proto.OP_RESIDENCY,
                                 np.zeros(int(n), np.uint8), int(n))
             for i in range(self.n_shards)])

    # -- stats ----------------------------------------------------------
    def shard_stats(self) -> List[Dict[str, Any]]:
        """Raw per-shard stats dicts (hit rates, bytes, telemetry) —
        surfaced through ``SenecaService.stats()["shards"]``.  A dead
        shard reports a zeroed marker entry with ``"dead": True``."""
        return [self._call_failover(
                    i, proto.OP_STATS,
                    {"shard": i, "dead": True, "hits": 0, "misses": 0,
                     "bytes_used": 0, "disk_bytes_used": 0})
                for i in range(self.n_shards)]

    def hit_rate(self) -> float:
        ss = self.shard_stats()
        h = sum(s["hits"] for s in ss)
        m = sum(s["misses"] for s in ss)
        return h / (h + m) if h + m else 0.0

    def bytes_used(self) -> int:
        return sum(s["bytes_used"] for s in self.shard_stats())

    def disk_bytes_used(self) -> int:
        return sum(s["disk_bytes_used"] for s in self.shard_stats())

    def hbm_bytes_used(self) -> int:
        return sum(s.get("hbm_bytes_used", 0) for s in self.shard_stats())

    def production_stats(self) -> Dict[str, float]:
        """Single-flight production counters summed across shards.
        Shard tables run in observe mode, so ``duplicates`` counts
        concurrent same-key productions that client-side coalescing
        did not absorb — the residual duplicate work reaching shards."""
        out: Dict[str, float] = {"led": 0, "coalesced": 0,
                                 "coalesce_wait_s": 0.0, "duplicates": 0,
                                 "in_flight": 0}
        for s in self.shard_stats():
            p = s.get("production") or {}
            for k in out:
                out[k] += p.get(k, 0)
        return out

    def spill_stats(self) -> Dict[str, Dict[str, int]]:
        if not self.has_spill:
            return {}
        return self._merge_form_stats("spill")

    def hbm_stats(self) -> Dict[str, Dict[str, int]]:
        if not self.has_hbm:
            return {}
        return self._merge_form_stats("hbm")

    def _merge_form_stats(self, key: str) -> Dict[str, Dict[str, int]]:
        """Sum the per-form counter dicts every shard reports under
        ``key`` (capacities and byte counters add across disjoint
        shards)."""
        merged: Dict[str, Dict[str, int]] = {}
        for s in self.shard_stats():
            for form, d in (s.get(key) or {}).items():
                agg = merged.setdefault(form, dict.fromkeys(d, 0))
                for k, v in d.items():
                    agg[k] += v
        return merged

    # -- data plane ------------------------------------------------------
    def produce(self, sid: int, epoch_tag: int = 0,
                want_payload: bool = True):
        """Serve one augmented sample from its owning shard (shard-side
        fetch/decode/augment)."""
        value = self._call_failover(
            self._shard_of(sid), proto.OP_PRODUCE, None,
            int(sid), int(epoch_tag), bool(want_payload))
        return self._recv(value) if want_payload else value

    def ingest(self, ids, epoch_tag: int = 0, chunk: int = 64) -> int:
        """Drive the produce path for many ids: keys group by owning
        shard, and each shard's stream runs on its own client thread —
        over the process transport the N shard processes fetch/decode
        concurrently (the disaggregation benchmark's inner loop)."""
        ids = np.asarray(ids, np.int64)
        groups = self.router.group(ids)

        def drive(sid: int, sids: np.ndarray) -> int:
            done = 0
            for off in range(0, len(sids), chunk):
                done += self._call_failover(
                    sid, proto.OP_PRODUCE_MANY, 0,
                    [int(x) for x in sids[off:off + chunk]],
                    int(epoch_tag))
            return done

        items = [(sid, ids[idx]) for sid, idx in groups.items()]
        if len(items) <= 1:
            return sum(drive(sid, sids) for sid, sids in items)
        with ThreadPoolExecutor(max_workers=len(items)) as pool:
            return sum(pool.map(lambda it: drive(*it), items))

    # ------------------------------------------------------------------
    def _cleanup_dirs(self) -> None:
        if self._xchg is not None:
            shutil.rmtree(self._xchg, ignore_errors=True)
        if self.spill_dir is not None:
            # shards cleared their own files; drop the empty per-shard
            # subdirs (rmdir: anything unexpectedly left stays visible)
            for i in range(self.n_shards):
                try:
                    os.rmdir(os.path.join(self.spill_dir, f"shard-{i}"))
                except OSError:
                    pass

    def close(self) -> None:
        """Idempotent: CLOSE every shard through the transport (each
        clears its own spill files), then drop the exchange dir."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        try:
            self.transport.close()
        finally:
            self._cleanup_dirs()
