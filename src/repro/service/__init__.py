"""The disaggregated Seneca data plane (tf.data-service-style).

A consistent-hash :class:`ShardRouter` maps sample keys to N
:class:`CacheShard` instances — each with its own tiered cache,
shard-local form×tier MDP solve, and telemetry — behind a small
request/response protocol with two interchangeable transports:
in-process simulation (deterministic under the VirtualClock) and one
OS process per shard (payloads moved zero-copy via codec files +
``np.memmap``).  :class:`ShardedCache` is the client: a drop-in for
``TieredCache`` that ``SenecaServer(shards=N, shard_transport=...)``
constructs, so sessions and pipelines work unchanged.  See docs/API.md
"Sharded data plane".
"""
from repro.service.client import ShardedCache
from repro.service.proto import Request, Response
from repro.service.router import ShardRouter
from repro.service.shard import CacheShard, ShardConfig
from repro.service.transport import (ProcessTransport, ShardDownError,
                                     SimTransport, TRANSPORTS,
                                     make_transport)

__all__ = [
    "ShardRouter", "ShardedCache", "CacheShard", "ShardConfig",
    "Request", "Response", "SimTransport", "ProcessTransport",
    "ShardDownError", "TRANSPORTS", "make_transport",
]
