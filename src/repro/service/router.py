"""Consistent-hash ring mapping sample keys to cache shards.

Classic Karger ring with virtual nodes: each shard contributes
``vnodes`` points on a 64-bit circle, a key belongs to the owner of the
first point clockwise from its hash.  Two properties the tests pin
down:

* **balance** — with enough virtual nodes each shard owns ~1/N of the
  key space (max/min load ratio bounded);
* **minimal remapping** — the points of shard ``i`` depend only on
  ``(seed, i, vnode)``, so growing N→N+1 adds points without moving any
  existing ones: a key either keeps its owner or moves to the *new*
  shard, never between old shards.  Shrinking is the mirror image.

Hashing is a splitmix64-style mixer, NOT Python's builtin ``hash`` —
the builtin is salted per process (PYTHONHASHSEED), and a router whose
mapping changed across the client and its shard subprocesses would
route every key nowhere.  The mixer is implemented twice, bit-for-bit:
masked Python ints for scalar calls, ``np.uint64`` wraparound for the
vectorized batch path (asserted equal in tests).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

_MASK = (1 << 64) - 1
_GAMMA = 0x9E3779B97F4A7C15
_M1 = 0xBF58476D1CE4E5B9
_M2 = 0x94D049BB133111EB


def splitmix64(x: int) -> int:
    """Scalar splitmix64 finalizer (Steele et al.): a full-avalanche
    deterministic 64-bit mixer."""
    x = (x + _GAMMA) & _MASK
    x = ((x ^ (x >> 30)) * _M1) & _MASK
    x = ((x ^ (x >> 27)) * _M2) & _MASK
    return x ^ (x >> 31)


def _splitmix64_np(x: np.ndarray) -> np.ndarray:
    """Vectorized twin of :func:`splitmix64` (uint64 wraparound)."""
    x = x.astype(np.uint64) + np.uint64(_GAMMA)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(_M1)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(_M2)
    return x ^ (x >> np.uint64(31))


class ShardRouter:
    """Key → shard assignment via a consistent-hash ring.

    ``seed`` diversifies both the ring points and the key salt, so two
    routers with different seeds give independent assignments; the same
    ``(n_shards, vnodes, seed)`` triple always rebuilds the identical
    ring in any process.
    """

    def __init__(self, n_shards: int, vnodes: int = 64, seed: int = 0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.n_shards = int(n_shards)
        self.vnodes = int(vnodes)
        self.seed = int(seed)
        self._key_salt = splitmix64(self.seed ^ 0xA5A5A5A5A5A5A5A5)
        points: List[int] = []
        owners: List[int] = []
        for shard in range(self.n_shards):
            for v in range(self.vnodes):
                # depends only on (seed, shard, v): adding shard N
                # leaves every existing point in place
                h = splitmix64(self.seed ^ splitmix64(
                    (shard << 20) | v))
                points.append(h)
                owners.append(shard)
        order = np.argsort(np.asarray(points, np.uint64), kind="stable")
        self._points = np.asarray(points, np.uint64)[order]
        self._owners = np.asarray(owners, np.int64)[order]

    # ------------------------------------------------------------------
    def _locate(self, hashes: np.ndarray) -> np.ndarray:
        """Ring walk: index of the first point clockwise of each hash
        (wrapping past the top back to point 0)."""
        idx = np.searchsorted(self._points, hashes, side="left")
        idx[idx == len(self._points)] = 0
        return idx

    def shard_of(self, key: int) -> int:
        """Owning shard of one sample key."""
        if self.n_shards == 1:
            return 0
        h = splitmix64((int(key) ^ self._key_salt) & _MASK)
        return int(self._owners[self._locate(
            np.asarray([h], np.uint64))[0]])

    def shard_of_many(self, keys) -> np.ndarray:
        """Vectorized :meth:`shard_of`: int64[len(keys)]."""
        keys = np.asarray(keys, np.int64)
        if self.n_shards == 1:
            return np.zeros(len(keys), np.int64)
        h = _splitmix64_np(keys.astype(np.uint64)
                           ^ np.uint64(self._key_salt))
        return self._owners[self._locate(h)]

    def group(self, keys) -> Dict[int, np.ndarray]:
        """Partition ``keys`` by owner: ``{shard: index array}`` where
        the indices point into the input sequence (order-preserving
        within each shard)."""
        owners = self.shard_of_many(keys)
        return {int(s): np.nonzero(owners == s)[0]
                for s in np.unique(owners)}

    def load(self, keys) -> np.ndarray:
        """Keys-per-shard histogram (the balance property's subject)."""
        return np.bincount(self.shard_of_many(keys),
                           minlength=self.n_shards)
