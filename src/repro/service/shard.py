"""One shard of the disaggregated data plane.

A :class:`CacheShard` owns 1/N of the key space: its own
:class:`~repro.cache.store.TieredCache` (sized to 1/N of the global
budget, split by a shard-local form×tier MDP solve unless a split is
pinned), its own telemetry aggregator, and — when configured with a
dataset — the full produce path (storage fetch → decode → augment),
which is what makes process-transport shards useful on a multi-core
host: the CPU-heavy decode runs in the shard process, outside the
client's GIL.

The shard is transport-agnostic: ``handle(Request) -> Response`` is the
entire surface.  The sim transport calls it directly on the job thread
(synchronous, deterministic under the VirtualClock turn discipline);
the process transport calls it from a pipe-reading loop in a child
process.  Exceptions never escape ``handle`` — they come back as
``Response(ok=False, error=...)`` so a poisoned request cannot kill a
shard.

Import discipline: this module must not import ``repro.api`` at module
level (``repro.api.__init__`` pulls in ``api/server.py``, which lazily
constructs the sharded client — a top-level import here would close the
cycle).  ``TelemetryAggregator`` is imported inside ``__init__``.
"""
from __future__ import annotations

import itertools
import os
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.cache.codecs import PayloadRef, receive_payload, ship_payload
from repro.cache.coalesce import ProductionTable
from repro.cache.store import FORMS, TieredCache
from repro.data.augment import augment_np
from repro.service import proto


def produce_seed(epoch_tag: int, sid: int) -> int:
    """The augment RNG seed for (epoch, sample) — the same derivation
    as the in-process pipeline's ``_aug_seed`` (repro/data/pipeline.py),
    duplicated here so shard processes need no pipeline import; the
    parity is pinned by tests/test_service.py."""
    return (epoch_tag * 1_000_003 + sid) & 0x7FFFFFFF


class _FitsGate:
    """Capacity-only admission for shards configured without a policy
    instance (the metadata-plane ``wants`` vote already happened client
    side; the shard re-checks only what must be atomic with the put)."""

    name = "fits"

    def fits(self, part, nbytes: int) -> bool:
        return part.admits(nbytes)


@dataclass
class ShardConfig:
    """Everything a shard needs to build itself — picklable, because the
    process transport ships it as the spawn argument (the dataset must
    therefore be a picklable profile like ``SyntheticDataset``, not a
    live handle)."""

    shard_id: int
    n_shards: int
    cache_bytes: int
    #: DRAM split; None -> shard-local MDP solve from the profiles below
    split: Optional[Tuple[float, float, float]] = None
    evict_policies: Optional[Dict[str, str]] = None
    #: admission policy instance (duck-typed ``.fits``); None -> capacity
    admission: Any = None
    spill_dir: Optional[str] = None
    spill_bytes: int = 0
    spill_split: Optional[Tuple[float, float, float]] = None
    #: device-resident tier budget (this shard's 1/N slice) + split
    hbm_bytes: int = 0
    hbm_split: Optional[Tuple[float, float, float]] = None
    #: profiles feeding the per-shard MDP solve (used when split=None)
    hardware: Any = None
    dataset_profile: Any = None
    job: Any = None
    partition_step: float = 0.01
    #: dataset + per-shard ingest bandwidth for the produce data plane
    dataset: Any = None
    storage_bandwidth: Optional[float] = None
    seed: int = 0
    #: payload exchange directory; None -> values travel in-band (sim)
    exchange_dir: Optional[str] = None


class CacheShard:
    """The server half of the protocol: one tiered cache + telemetry +
    produce path behind :meth:`handle`."""

    def __init__(self, cfg: ShardConfig):
        from repro.api.telemetry import TelemetryAggregator  # lazy: cycle

        self.cfg = cfg
        split = tuple(cfg.split) if cfg.split is not None else None
        spill_split = (tuple(cfg.spill_split)
                       if cfg.spill_split is not None else None)
        has_spill = cfg.spill_dir is not None and cfg.spill_bytes > 0
        has_hbm = cfg.hbm_bytes > 0
        hbm_split = (tuple(cfg.hbm_split)
                     if cfg.hbm_split is not None else None)
        self.partition_label = None
        if split is None:
            if cfg.hardware is None or cfg.dataset_profile is None:
                raise ValueError(
                    f"shard {cfg.shard_id}: no split pinned and no "
                    "hardware/dataset profiles to solve one from")
            from repro.core import mdp
            solved = mdp.optimize_shard(
                cfg.hardware, cfg.dataset_profile, cfg.job,
                n_shards=cfg.n_shards, step=cfg.partition_step,
                tiered=has_spill or has_hbm)
            if has_spill or has_hbm:
                split = (solved.dram.x_e, solved.dram.x_d, solved.dram.x_a)
                if has_spill and spill_split is None:
                    spill_split = (solved.disk.x_e, solved.disk.x_d,
                                   solved.disk.x_a)
                if has_hbm and hbm_split is None and solved.hbm is not None:
                    hbm_split = (solved.hbm.x_e, solved.hbm.x_d,
                                 solved.hbm.x_a)
            else:
                split = (solved.x_e, solved.x_d, solved.x_a)
            self.partition_label = solved.label
        self.split = split
        self.cache = TieredCache(
            cfg.cache_bytes, split,
            evict_policies=cfg.evict_policies,
            spill_bytes=cfg.spill_bytes if has_spill else 0,
            spill_dir=cfg.spill_dir if has_spill else None,
            spill_split=spill_split,
            hbm_bytes=cfg.hbm_bytes if has_hbm else 0,
            hbm_split=hbm_split if has_hbm else None)
        self.admission = cfg.admission or _FitsGate()
        self.telemetry = TelemetryAggregator()
        self.dataset = cfg.dataset
        self.storage = None
        if cfg.dataset is not None:
            from repro.data.storage import RemoteStorage
            self.storage = RemoteStorage(cfg.dataset,
                                         bandwidth=cfg.storage_bandwidth)
        self._seq = itertools.count()
        self.produced = 0
        # observe-mode single-flight table: shards must never block a
        # request on another request's production (the sim transport
        # may carry a virtual clock whose turn discipline a wall wait
        # would wedge), so concurrent same-key productions proceed and
        # are *counted* as duplicates instead of coalesced here —
        # cross-job dedup happens client-side in DSIPipeline
        self.production = ProductionTable(enabled=False)
        self._closed = False

    # -- payload marshalling -------------------------------------------
    def _ship(self, form: Optional[str], value: Any) -> Any:
        """Outbound payload: park it in the exchange dir and send the
        ref (process transport) or pass the object through (sim)."""
        if self.cfg.exchange_dir is None or form is None or value is None:
            return value
        if not isinstance(value, (bytes, np.ndarray)):
            # device-resident (HBM-tier) arrays leave the shard as host
            # copies; the client side receives a plain ndarray
            value = np.asarray(value)
        path = os.path.join(
            self.cfg.exchange_dir,
            f"s{self.cfg.shard_id}-{os.getpid()}-{next(self._seq)}.bin")
        return ship_payload(form, value, path)

    @staticmethod
    def _recv(value: Any) -> Any:
        return (receive_payload(value)
                if isinstance(value, PayloadRef) else value)

    # -- dispatch -------------------------------------------------------
    def handle(self, req: proto.Request) -> proto.Response:
        fn = self._OPS.get(req.op)
        if fn is None:
            return proto.Response(
                False, error=f"unknown op {req.op!r}",
                version=self.cache.version)
        try:
            value = fn(self, *req.args)
        except Exception as e:  # shards survive poisoned requests
            return proto.Response(
                False, error=f"{type(e).__name__}: {e}",
                evicted=tuple(self.cache.take_evicted()),
                version=self.cache.version)
        return proto.Response(
            True, value,
            evicted=tuple(self.cache.take_evicted()),
            version=self.cache.version)

    # -- control-plane ops ---------------------------------------------
    def _op_ping(self):
        return {"shard": self.cfg.shard_id,
                "split": tuple(self.split),
                "partition": self.partition_label,
                "caps": {form: self.cache.total_capacity(form)
                         for form in FORMS}}

    def _op_lookup(self, key: int):
        t0 = time.monotonic()
        form, value, tier = self.cache.lookup_tiered(key)
        self.telemetry.record_serve(form)
        if form is not None:
            nbytes = (value.nbytes if hasattr(value, "nbytes")
                      else len(value))
            self.telemetry.record_bytes(
                "disk" if tier == "disk" else "cache",
                nbytes, time.monotonic() - t0)
        return form, self._ship(form, value), tier

    def _op_insert(self, key, form, value, nbytes, gated):
        value = self._recv(value)
        if gated:
            return self.cache.insert_gated(key, form, value, nbytes,
                                           self.admission)
        return self.cache.insert(key, form, value, nbytes)

    def _op_insert_batch(self, form, entries):
        entries = [(k, self._recv(v), nb) for k, v, nb in entries]
        return self.cache.insert_batch_gated(form, entries,
                                             self.admission)

    def _op_evict(self, key, form):
        return self.cache.evict(key, form)

    def _op_contains(self, form, keys):
        return self.cache.contains_many(form, keys)

    def _op_serving_forms(self, keys):
        return self.cache.serving_forms(keys)

    def _op_form_of(self, key):
        return self.cache.form_of(key)

    def _op_free_bytes(self, form):
        return self.cache.chain_free_bytes(form)

    def _op_status(self, n):
        return self.cache.status_array(n)

    def _op_residency(self, n):
        return self.cache.residency_array(n)

    def _op_resize(self, split, spill_split, hbm_split=None):
        out = self.cache.resize(tuple(split),
                                tuple(spill_split) if spill_split else None,
                                tuple(hbm_split) if hbm_split else None)
        self.split = tuple(float(x) for x in split)
        return out

    def _op_set_costs(self, costs):
        self.cache.set_form_costs(dict(costs))
        return True

    def _op_stats(self):
        parts = self.cache.parts
        return {
            "shard": self.cfg.shard_id,
            "partition": self.partition_label,
            "split": tuple(self.split),
            "hits": sum(p.total_hits for p in parts.values()),
            "misses": (sum(p.total_misses for p in parts.values())
                       + self.cache.lookup_misses),
            "hit_rate": self.cache.hit_rate(),
            "bytes_used": self.cache.bytes_used(),
            "disk_bytes_used": self.cache.disk_bytes_used(),
            "hbm_bytes_used": self.cache.hbm_bytes_used(),
            "entries": sum(len(p) for p in parts.values()),
            "produced": self.produced,
            "production": self.production.stats(),
            "spill": self.cache.spill_stats(),
            "hbm": self.cache.hbm_stats(),
            "telemetry": self.telemetry.as_dict(),
        }

    def _op_close(self):
        self.close()
        return True

    # -- data-plane ops (the shard-side produce path) ------------------
    def _op_produce(self, sid, epoch_tag, want_payload):
        value = self._produce(int(sid), int(epoch_tag))
        self.produced += 1
        return (self._ship("augmented", value) if want_payload
                else int(value.nbytes))

    def _op_produce_many(self, sids, epoch_tag):
        tag = int(epoch_tag)
        for sid in sids:
            self._produce(int(sid), tag)
            self.produced += 1
        return len(sids)

    def _produce(self, sid: int, epoch_tag: int) -> np.ndarray:
        """Serve one augmented sample shard-locally, mirroring the
        pipeline's per-sample stage chain (cache short-circuits at the
        most-processed resident form; intermediate forms are offered to
        the cache through the shard's admission gate)."""
        if self.dataset is None:
            raise RuntimeError(
                f"shard {self.cfg.shard_id} has no dataset configured "
                "for produce")
        form, value, _tier = self.cache.lookup_tiered(sid)
        self.telemetry.record_serve(form)
        if form == "augmented":
            return value
        _leader, flight = self.production.begin(sid, "augmented")
        try:
            out = self._produce_miss(sid, epoch_tag, form, value)
        except BaseException as e:
            self.production.abort(flight, e)
            raise
        self.production.finish(flight, out)
        return out

    def _produce_miss(self, sid: int, epoch_tag: int,
                      form: Optional[str], value) -> np.ndarray:
        if form == "decoded":
            img = value
        else:
            if form == "encoded":
                enc = value
            else:
                t0 = time.monotonic()
                enc = self.storage.fetch(sid)
                dt = time.monotonic() - t0
                self.telemetry.record_stage("fetch_storage", dt)
                self.telemetry.record_bytes("storage", len(enc), dt)
                self.cache.insert_gated(sid, "encoded", enc, len(enc),
                                        self.admission)
            t1 = time.monotonic()
            img = self.dataset.decode(enc, sid)
            self.telemetry.record_stage("decode", time.monotonic() - t1)
            self.cache.insert_gated(sid, "decoded", img, img.nbytes,
                                    self.admission)
        t2 = time.monotonic()
        out = augment_np(img, self.dataset.crop_hw,
                         np.random.default_rng(produce_seed(epoch_tag,
                                                            sid)))
        self.telemetry.record_stage("augment", time.monotonic() - t2)
        self.cache.insert_gated(sid, "augmented", out, out.nbytes,
                                self.admission)
        return out

    # ------------------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.cache.close()

    _OPS = {
        proto.OP_PING: _op_ping,
        proto.OP_LOOKUP: _op_lookup,
        proto.OP_INSERT: _op_insert,
        proto.OP_INSERT_BATCH: _op_insert_batch,
        proto.OP_EVICT: _op_evict,
        proto.OP_CONTAINS: _op_contains,
        proto.OP_SERVING_FORMS: _op_serving_forms,
        proto.OP_FORM_OF: _op_form_of,
        proto.OP_FREE_BYTES: _op_free_bytes,
        proto.OP_STATUS: _op_status,
        proto.OP_RESIDENCY: _op_residency,
        proto.OP_RESIZE: _op_resize,
        proto.OP_SET_COSTS: _op_set_costs,
        proto.OP_STATS: _op_stats,
        proto.OP_PRODUCE: _op_produce,
        proto.OP_PRODUCE_MANY: _op_produce_many,
        proto.OP_CLOSE: _op_close,
    }
