"""Request/response protocol between the sharded-cache client and its
:class:`~repro.service.shard.CacheShard` fleet.

One tiny verb set covers everything the single-process
:class:`~repro.cache.store.TieredCache` surface needs (lookup / admit /
stats / resize / residency gathers) plus the data-plane ``produce`` ops
the sharded benchmark drives.  The same :class:`Request` /
:class:`Response` pair travels over both transports — called directly on
in-process shard objects (sim) or pickled over a pipe (process) — so a
test that drives the sim transport exercises byte-identical protocol
paths to production.

Substitution note: ODS *sampling* substitution (which sample fills a
batch slot) is a metadata-plane decision and stays in the central
service — shards only answer the *serving-form* half (``OP_LOOKUP`` /
``OP_SERVING_FORMS`` report the most-processed resident form, exactly
like ``TieredCache.lookup``).

Every :class:`Response` piggybacks two bookkeeping fields so the client
needs no polling RPCs:

* ``evicted`` — keys this shard's tier chains dropped as a side effect
  since the last response (spill overflow, promotion backfill); the
  client accumulates them for the service's ODS reconcile pass.
* ``version`` — the shard cache's residency version counter; the client
  sums shard versions into the composite version gating the O(N)
  residency-array rebuild.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

OP_PING = "ping"                   # -> shard hello (id, split, capacities)
OP_LOOKUP = "lookup"               # (key) -> (form, value|ref, tier)
OP_INSERT = "insert"               # (key, form, value, nbytes, gated)
OP_INSERT_BATCH = "insert_batch"   # (form, [(key, value, nbytes)])
OP_EVICT = "evict"                 # (key, form) -> bool
OP_CONTAINS = "contains"           # (form, [keys]) -> [bool]
OP_SERVING_FORMS = "serving_forms"  # ([keys]) -> [form|None]
OP_FORM_OF = "form_of"             # (key) -> form|None
OP_FREE_BYTES = "free_bytes"       # (form) -> chain free bytes
OP_STATUS = "status"               # (n) -> uint8[n] ODS status codes
OP_RESIDENCY = "residency"         # (n) -> uint8[n] residency levels
OP_RESIZE = "resize"               # (split, spill_split) -> {form: keys}
OP_SET_COSTS = "set_costs"         # ({form: seconds}) -> True
OP_STATS = "stats"                 # -> per-shard stats dict
OP_PRODUCE = "produce"             # (sid, epoch_tag, want_payload)
OP_PRODUCE_MANY = "produce_many"   # ([sids], epoch_tag) -> count
OP_CLOSE = "close"                 # -> True; shard tears down after reply


@dataclass(frozen=True)
class Request:
    """One shard call: a verb plus positional arguments."""

    op: str
    args: Tuple[Any, ...] = ()


@dataclass(frozen=True)
class Response:
    """The reply: ``value`` on success, ``error`` (a formatted
    exception, never a live traceback object) on failure, and the
    piggybacked eviction/version bookkeeping either way."""

    ok: bool
    value: Any = None
    error: Optional[str] = None
    evicted: Tuple[int, ...] = ()
    version: int = 0
