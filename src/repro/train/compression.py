"""Gradient compression for DP all-reduce: int8 with error feedback.

Used by the explicit-collective (shard_map) training path: each data-rank
quantizes its local gradient to int8 (per-block scales), all-reduces the
int32-accumulated payload, and keeps the quantization residual locally for
the next step (error feedback keeps the scheme unbiased over time).
4x fewer gradient bytes on the wire; convergence impact is tested in
tests/test_compression.py (loss trajectory within tolerance of fp32 DP).

The pjit path lets XLA place gradient reduce-scatters itself; compression
applies to the explicit path (train/dp_shard.py) and is the substrate for
the collective-bound §Perf iterations.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256


class EFState(NamedTuple):
    residual: Any          # pytree of fp32 residuals


def init_ef(params) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _blocks(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK), flat.shape[0]


def compress(g: jax.Array, residual: jax.Array
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (int8 payload, scales, new residual)."""
    corrected = g.astype(jnp.float32) + residual
    blocks, n = _blocks(corrected)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(g.shape)
    return q, scale, corrected - deq


def decompress(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def allreduce_compressed(grads, ef: EFState, axis_name: str
                         ) -> Tuple[Any, EFState]:
    """int8 error-feedback all-reduce over ``axis_name`` (inside shard_map).

    The int8 payloads are psum'd as int32 (lossless accumulation across
    ranks given per-rank scales are folded in before the sum).
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        blocks, n = _blocks(corrected)
        # 1) agree on a shared per-block scale (tiny fp32 collective)
        local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jax.lax.pmax(local_scale, axis_name)
        # 2) quantize against the shared scale; residual stays local
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                     -127, 127).astype(jnp.int8)
        deq_local = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
        new_r = corrected - deq_local.reshape(g.shape)
        # 3) int32-accumulated all-reduce of the int8 payload (the wire
        #    traffic is 1B/element + the scale sidecar)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        world = jax.lax.psum(1, axis_name)
        mean = total.astype(jnp.float32) * scale / world
        return mean.reshape(-1)[:n].reshape(g.shape), new_r

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(leaves_g, leaves_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            EFState(treedef.unflatten([o[1] for o in outs])))
