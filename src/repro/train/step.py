"""Train-step builder: loss + grad (+ microbatch accumulation) + optimizer.

``build_train_step(model, parallel, opt)`` returns a pure
``step(params, opt_state, batch) -> (params', opt_state', metrics)``
suitable for jit/pjit.  Gradient accumulation runs as a ``lax.scan`` over
microbatches with the per-layer remat policy applied inside, so activation
memory is bounded by one microbatch regardless of global batch.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelismConfig
from repro.models.model import Model
from repro.train.optimizer import AdamW, AdamWState


def _split_microbatches(batch: Dict, n: int) -> Dict:
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def build_train_step(model: Model, parallel: ParallelismConfig,
                     opt: AdamW) -> Callable:
    remat = parallel.remat
    n_micro = parallel.microbatches

    def loss_fn(params, mb):
        return model.loss(params, mb, remat=remat)

    def step(params, opt_state: AdamWState, batch):
        if n_micro > 1:
            mbs = _split_microbatches(batch, n_micro)

            def acc(carry, mb):
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree.map(jnp.add, carry, g), loss

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(acc, zero, mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state, gnorm = opt.update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_params, new_state, metrics

    return step


def build_eval_step(model: Model) -> Callable:
    def step(params, batch):
        return model.loss(params, batch)
    return step
