"""AdamW with memory-tiered optimizer state (no optax dependency).

Moment dtype options per ParallelismConfig.opt_state_dtype:
* ``float32``  — classic AdamW;
* ``bfloat16`` — halves optimizer HBM (used for the >=100B dense archs);
* ``int8``     — blockwise-quantized moments (scale per trailing block of
  256), the trick that lets kimi-k2-1t train on 2 pods (DESIGN.md §3 /
  EXPERIMENTS.md memory budget).

States inherit the parameter PartitionSpecs, so FSDP shards them over
'data' automatically.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256


class Quantized(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # fp32 per-block scales


def _blocks(x: jax.Array) -> Tuple[jax.Array, bool]:
    """Blocked view.  Structure-preserving when the trailing axis divides
    QBLOCK: shape (..., D) -> (..., D/Q, Q), so the quantized state keeps
    the parameter's leading axes and inherits its PartitionSpec — without
    this, sharded optimizers re-shard full fp32 moment tensors every step
    (the §Perf kimi-k2 iteration-2 finding: 7.7 TB/step of all-gathers)."""
    if x.ndim >= 1 and x.shape[-1] % QBLOCK == 0:
        return x.reshape(*x.shape[:-1], x.shape[-1] // QBLOCK, QBLOCK), True
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK), False


def _unblocks(blocks: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    if len(shape) >= 1 and shape[-1] % QBLOCK == 0 and \
            blocks.ndim == len(shape) + 1:
        return blocks.reshape(shape)
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape)


def _quantize(x: jax.Array) -> Quantized:
    """Signed symmetric absmax int8 (for the first moment)."""
    blocks, _ = _blocks(x)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return Quantized(q, scale.astype(jnp.float32))


def _dequantize(qv: Quantized, shape: Tuple[int, ...]) -> jax.Array:
    return _unblocks(qv.q.astype(jnp.float32) * qv.scale, shape)


def _quantize_pos(x: jax.Array) -> Quantized:
    """Fourth-root uint8 coding for the (non-negative) second moment —
    covers ~8 decades of dynamic range per block (8-bit-Adam-style dynamic
    map; symmetric absmax collapses small v entries to 0 and the update
    m/(sqrt(0)+eps) explodes)."""
    blocks, _ = _blocks(x)
    vmax = jnp.max(blocks, axis=-1, keepdims=True)
    root = jnp.sqrt(jnp.sqrt(blocks / jnp.maximum(vmax, 1e-30)))
    q = jnp.round(root * 255.0).astype(jnp.uint8)
    return Quantized(q, vmax.astype(jnp.float32))


def _dequantize_pos(qv: Quantized, shape: Tuple[int, ...]) -> jax.Array:
    root = qv.q.astype(jnp.float32) / 255.0
    return _unblocks((root ** 4) * qv.scale, shape)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any                # pytree matching params (dtype-tiered)
    v: Any


class AdamW:
    def __init__(self, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, grad_clip=1.0,
                 state_dtype: str = "float32",
                 schedule=None):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.wd = weight_decay
        self.clip = grad_clip
        self.state_dtype = state_dtype
        self.schedule = schedule

    # -- state representation helpers --
    def _to_state(self, x: jax.Array, positive: bool = False):
        if self.state_dtype == "int8":
            return _quantize_pos(x) if positive else _quantize(x)
        if self.state_dtype == "bfloat16":
            return x.astype(jnp.bfloat16)
        return x.astype(jnp.float32)

    def _from_state(self, s, shape, positive: bool = False):
        if self.state_dtype == "int8":
            return _dequantize_pos(s, shape) if positive \
                else _dequantize(s, shape)
        return s.astype(jnp.float32)

    def init(self, params) -> AdamWState:
        def z(p, positive):
            return self._to_state(jnp.zeros(p.shape, jnp.float32), positive)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(lambda p: z(p, False), params),
            v=jax.tree.map(lambda p: z(p, True), params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr if self.schedule is None else self.schedule(step)

        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip / jnp.maximum(gnorm, 1e-12)) \
            if self.clip else 1.0

        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        is_q = self.state_dtype == "int8"

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            mf = self._from_state(m, p.shape)
            vf = self._from_state(v, p.shape, positive=True)
            mf = self.b1 * mf + (1 - self.b1) * g
            vf = self.b2 * vf + (1 - self.b2) * g * g
            mh = mf / b1c
            vh = vf / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.wd and p.ndim >= 2:       # no decay on norms/biases
                delta = delta + self.wd * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return (new_p, self._to_state(mf),
                    self._to_state(vf, positive=True))

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_m = treedef.flatten_up_to(state.m) if not is_q else \
            jax.tree.flatten(state.m, is_leaf=lambda x: isinstance(
                x, Quantized))[0]
        leaves_v = treedef.flatten_up_to(state.v) if not is_q else \
            jax.tree.flatten(state.v, is_leaf=lambda x: isinstance(
                x, Quantized))[0]
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(leaves_p, leaves_g, leaves_m, leaves_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return f
