"""Explicit-collective data-parallel trainer (shard_map path).

The pjit path lets XLA schedule gradient reductions; this path makes them
explicit so the framework can (a) compress gradients on the wire
(train/compression.py) and (b) overlap the reduction with the optimizer
prologue.  Used by the multi-device integration tests and the gradient-
compression §Perf iteration.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.models.model import Model
from repro.train import compression
from repro.train.optimizer import AdamW


def build_dp_train_step(model: Model, opt: AdamW, mesh: Mesh,
                        axis: str = "data",
                        compress_grads: bool = False) -> Callable:
    """Params replicated; batch sharded over ``axis``; explicit psum."""

    def local_step(params, opt_state, ef, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        if compress_grads:
            grads, ef = compression.allreduce_compressed(grads, ef, axis)
        else:
            grads = jax.lax.pmean(grads, axis)
        loss = jax.lax.pmean(loss, axis)
        new_params, new_state, gnorm = opt.update(grads, opt_state, params)
        return new_params, new_state, ef, {"loss": loss, "grad_norm": gnorm}

    batch_specs = {"tokens": P(axis, None), "labels": P(axis, None)}

    def spec_for_batch(batch):
        return {k: P(axis) if v.ndim == 1 else
                P(*((axis,) + (None,) * (v.ndim - 1)))
                for k, v in batch.items()}

    def step(params, opt_state, ef, batch):
        in_specs = (P(), P(), P(), spec_for_batch(batch))
        out_specs = (P(), P(), P(), P())
        f = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
        return f(params, opt_state, ef, batch)

    return step
