"""Deprecated shim — the service engine moved to :mod:`repro.api`.

The Figure-7 glue (MDP partitioning + ODS sampling + tiered cache) now
lives behind the session facade::

    from repro.api import SenecaServer
    server = SenecaServer.for_dataset(ds)
    with server.open_session(batch_size=32) as sess:
        ids, forms = sess.next_batch_ids()

``SenecaService`` / ``SenecaConfig`` keep working from here for old
callers; new code should import from :mod:`repro.api`.
"""
from __future__ import annotations

import warnings

from repro.api.server import (CODE_FORM, FORM_CODE, SenecaConfig,
                              SenecaServer, SenecaService, Session,
                              SessionClosed)

__all__ = ["SenecaConfig", "SenecaService", "SenecaServer", "Session",
           "SessionClosed", "FORM_CODE", "CODE_FORM"]

# Removal postponed 2026-10-01 -> 2026-12-01: the original date had not
# yet passed when the fault-tolerance refactor landed, and downstream
# benchmark forks still import from here; one more deprecation cycle
# gives them a release window to move to repro.api before deletion.
warnings.warn(
    "repro.core.seneca is deprecated and will be REMOVED after 2026-12-01; "
    "import SenecaServer / SenecaService from repro.api "
    "instead. The legacy positional DSIPipeline(job_id, service, storage, "
    "batch_size) call style is scheduled for removal on the same date.",
    DeprecationWarning, stacklevel=2)
