"""Seneca: MDP + ODS + tiered cache, glued into a data-loader service.

This is the paper's Figure 7 as a composable object:

* at construction, **MDP** partitions the cache from the performance model
  (hardware profile x dataset profile x job profile);
* at runtime, **ODS** substitutes cache misses with unseen hits per job,
  maintains the seen/status/refcount metadata, and triggers the
  refcount-threshold eviction + background refill of the augmented tier.

Multiple concurrent jobs (the paper's headline scenario) register against
one ``SenecaService``; see examples/concurrent_training.py.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.cache.store import TieredCache
from repro.core import mdp
from repro.core.ods import (AUGMENTED, DECODED, ENCODED, IN_STORAGE,
                            EpochSampler, ODSState)
from repro.core.perf_model import (DatasetProfile, HardwareProfile,
                                   JobProfile)

FORM_CODE = {"encoded": ENCODED, "decoded": DECODED, "augmented": AUGMENTED}
CODE_FORM = {v: k for k, v in FORM_CODE.items()}


@dataclass
class SenecaConfig:
    cache_bytes: int
    hardware: HardwareProfile
    dataset: DatasetProfile
    job: JobProfile = field(default_factory=JobProfile)
    partition_step: float = 0.01
    seed: int = 0
    use_ods: bool = True          # False -> MDP-only (paper's "MDP" bar)
    # manual override (x_e, x_d, x_a); None -> run MDP
    split: Optional[Tuple[float, float, float]] = None


class SenecaService:
    """One shared dataset's cache + sampler service."""

    def __init__(self, cfg: SenecaConfig):
        self.cfg = cfg
        if cfg.split is not None:
            self.partition = mdp.Partition(*cfg.split, throughput=float("nan"))
        else:
            hw = cfg.hardware
            if hw.s_cache != cfg.cache_bytes:
                from dataclasses import replace
                hw = replace(hw, s_cache=float(cfg.cache_bytes))
            self.partition = mdp.optimize(hw, cfg.dataset, cfg.job,
                                          cfg.partition_step)
        self.cache = TieredCache(
            cfg.cache_bytes,
            (self.partition.x_e, self.partition.x_d, self.partition.x_a))
        self.ods = ODSState.create(cfg.dataset.n_total, seed=cfg.seed)
        self.rng = np.random.default_rng(cfg.seed + 1)
        self._samplers: Dict[int, EpochSampler] = {}
        self._lock = threading.Lock()
        self._refill_pending: List[int] = []

    # ------------------------------------------------------------------
    def register_job(self, job_id: int, batch_size: int) -> None:
        with self._lock:
            self.ods.register_job(job_id)
            self._samplers[job_id] = EpochSampler(
                self.cfg.dataset.n_total, batch_size,
                self.cfg.seed + 97 * (job_id + 1))

    def unregister_job(self, job_id: int) -> None:
        with self._lock:
            self.ods.unregister_job(job_id)
            self._samplers.pop(job_id, None)

    # ------------------------------------------------------------------
    def next_batch_ids(self, job_id: int) -> Tuple[np.ndarray, np.ndarray]:
        """Sample a batch for ``job_id``.

        Returns (ids, forms): forms is the uint8 status of each id, i.e.
        which tier will serve it (0 = storage fetch).
        """
        with self._lock:
            requested = self._samplers[job_id].next_request()
            if self.cfg.use_ods:
                batch, evicted = self.ods.sample_batch(job_id, requested)
                if len(evicted):
                    for k in evicted:
                        self.cache.evict(int(k), "augmented")
                    self._refill_pending.extend(int(k) for k in evicted)
            else:
                batch = requested
                # MDP-only still tracks hits/misses for stats
                cached = self.ods.status[batch] != IN_STORAGE
                self.ods.hits += int(cached.sum())
                self.ods.misses += int((~cached).sum())
            forms = self.ods.status[batch].copy()
            return batch, forms

    # ------------------------------------------------------------------
    def admit(self, sample_id: int, form: str, value, nbytes: int) -> bool:
        """Insert a sample into its tier; updates ODS status on success.

        Augmented admissions that no job could still consume this epoch
        (all seen-bits set) are rejected — they would pin a slot until the
        epoch rollover without serving anyone.
        """
        with self._lock:
            if form == "augmented" and self.cfg.use_ods and \
                    self.ods.admission_value(sample_id) == 0:
                return False
        ok = self.cache.insert(sample_id, form, value, nbytes)
        if ok:
            with self._lock:
                self.ods.mark_cached(np.asarray([sample_id]),
                                     FORM_CODE[form])
        return ok

    def refill_candidates(self, k: int) -> np.ndarray:
        """Background-refill picks: random storage-resident samples
        (paper step 5: evicted slots repopulate pseudo-randomly)."""
        with self._lock:
            pool = np.flatnonzero(self.ods.status == IN_STORAGE)
            if not len(pool):
                return pool
            return self.rng.choice(pool, size=min(k, len(pool)),
                                   replace=False)

    def take_refill_work(self, max_n: int = 64) -> np.ndarray:
        """Claim pending eviction slots and return fresh random samples to
        preprocess into them (the paper's background-refill thread body)."""
        with self._lock:
            n = min(len(self._refill_pending), max_n)
            if not n:
                return np.empty(0, np.int64)
            del self._refill_pending[:n]
        return self.refill_candidates(n)

    def lookup(self, sample_id: int):
        return self.cache.lookup(sample_id)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {
            "partition": self.partition.label,
            "predicted_throughput": self.partition.throughput,
            "ods_hit_rate": self.ods.hit_rate(),
            "substitutions": self.ods.substitutions,
            "cache_bytes_used": self.cache.bytes_used(),
            "metadata_bytes": self.ods.metadata_bytes(),
        }
