"""Opportunistic Data Sampling (Seneca §5.2).

Vectorized reimplementation of the paper's per-sample loop (DESIGN.md §2):
the metadata is exactly the paper's — a per-job *seen* bit-vector, a
per-dataset *status* byte and a *reference count* — but substitution is a
masked argsort over the batch instead of pointer chasing, so a batch costs
O(B log B + candidates) numpy time and has a direct jittable twin
(:mod:`repro.core.ods_jax`).

Guarantees (§5.2, tested in tests/test_ods.py):
  1. a job sees every dataset sample exactly once per epoch;
  2. an augmented sample is never reused across epochs (refcount eviction
     at threshold = number of registered jobs);
  3. the delivered order remains pseudo-random (substitutions depend only
     on cache state and the job's PRNG).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

# status byte values (paper: 1B per sample encodes status + refcount)
IN_STORAGE = 0
ENCODED = 1
DECODED = 2
AUGMENTED = 3


@dataclass
class ODSState:
    """Shared per-dataset state + per-job seen bit-vectors."""
    n_samples: int
    status: np.ndarray                    # uint8[N]
    refcount: np.ndarray                  # int32[N] (augmented-tier refs)
    seen: Dict[int, np.ndarray] = field(default_factory=dict)
    epoch: Dict[int, int] = field(default_factory=dict)
    served: Dict[int, int] = field(default_factory=dict)
    rng: np.random.Generator = field(
        default_factory=lambda: np.random.default_rng(0))
    # per-tier residency levels (0 storage / 1 disk / 2 DRAM), pushed by
    # the service when the cache has a spill tier; None = single-tier
    # cache, substitution stays byte-identical to the paper's
    residency: Optional[np.ndarray] = None
    # bool[N] mask of samples with an in-flight production (the
    # single-flight coalescing table), pushed by the service per batch;
    # None = table idle — every draw stays byte-identical to the
    # mask-free path.  Uncached fills prefer non-in-flight ids so
    # concurrent jobs fan out over distinct keys instead of piling onto
    # productions already being coalesced
    inflight: Optional[np.ndarray] = None
    # stats
    hits: int = 0
    misses: int = 0
    substitutions: int = 0

    @classmethod
    def create(cls, n_samples: int, seed: int = 0) -> "ODSState":
        return cls(n_samples=n_samples,
                   status=np.zeros(n_samples, np.uint8),
                   refcount=np.zeros(n_samples, np.int32),
                   rng=np.random.default_rng(seed))

    # ------------------------------------------------------------------
    def register_job(self, job_id: int) -> None:
        self.seen[job_id] = np.zeros(self.n_samples, bool)
        self.epoch[job_id] = 0
        self.served[job_id] = 0

    def unregister_job(self, job_id: int) -> None:
        self.seen.pop(job_id, None)
        self.epoch.pop(job_id, None)
        self.served.pop(job_id, None)

    @property
    def n_jobs(self) -> int:
        return max(len(self.seen), 1)

    def metadata_bytes(self) -> int:
        """Paper §5.2: ~1 bit/job/sample + 1 B/sample."""
        return self.n_samples * len(self.seen) // 8 + self.n_samples

    # ------------------------------------------------------------------
    def mark_cached(self, ids: np.ndarray, form: int) -> None:
        self.status[ids] = form
        if form == AUGMENTED:
            # an augmented tensor admitted via the serving path was already
            # consumed by the jobs whose seen-bit is set; start the
            # reference count there so threshold eviction still fires after
            # the *remaining* jobs use it (paper §5.2 semantics: evict once
            # every job consumed the augmentation once)
            if self.seen:
                seen_count = np.zeros(len(ids), np.int32)
                for bits in self.seen.values():
                    seen_count += bits[ids].astype(np.int32)
                self.refcount[ids] = seen_count
            else:
                self.refcount[ids] = 0

    def admission_value(self, sample_id: int) -> int:
        """How many jobs could still be served by caching this sample's
        augmented form (0 -> not worth a slot)."""
        return self.n_jobs - int(sum(bits[sample_id]
                                     for bits in self.seen.values()))

    def mark_evicted(self, ids: np.ndarray) -> None:
        self.status[ids] = IN_STORAGE
        self.refcount[ids] = 0

    def set_residency(self, levels: Optional[np.ndarray]) -> None:
        """Install the cache's per-sample tier levels (uint8[N]: 0
        storage / 1 disk / 2 DRAM).  When set, substitution prefers
        DRAM-resident candidates over disk-resident ones — a disk hit
        still beats a storage fetch, but not a DRAM hit."""
        self.residency = levels

    def set_inflight(self, mask: Optional[np.ndarray]) -> None:
        """Install the coalescing table's in-flight mask (bool[N], or
        None when the table is idle).  When set, substitution and
        uncached fills deprioritize in-flight ids — another job is
        already producing them, so a different pick costs the same and
        widens aggregate coverage."""
        self.inflight = mask

    # ------------------------------------------------------------------
    def sample_batch(self, job_id: int, requested: np.ndarray,
                     evict_threshold: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """ODS steps 1–4 (Fig. 6) for one batch request.

        ``requested`` is the next slice of the job's pseudo-random epoch
        permutation.  Returns (batch ids, eviction ids).  Slots whose
        requested sample misses in the cache (or was already consumed as an
        earlier substitute) are opportunistically replaced by cached,
        unseen samples; slots with no candidate keep the storage fetch.

        ``evict_threshold`` overrides the step-5 refcount threshold
        (default: the registered job count, the paper's rule; eviction
        policies pass a large sentinel to disable refcount churn).
        """
        seen = self.seen[job_id]
        requested = np.asarray(requested)
        B = len(requested)

        # epoch rollover: not enough unseen samples left for this batch
        if self.n_samples - self.served[job_id] < B:
            seen[:] = False
            self.served[job_id] = 0
            self.epoch[job_id] += 1

        cached_req = self.status[requested] != IN_STORAGE
        unseen_req = ~seen[requested]
        direct = cached_req & unseen_req            # serve as-is (hits)
        replace_slots = np.flatnonzero(~direct)     # misses + already-seen

        batch = requested.copy()
        if len(replace_slots):
            # candidates: cached, unseen, not already part of this batch
            cand_mask = (self.status != IN_STORAGE) & ~seen
            cand_mask[requested[direct]] = False
            cand = np.flatnonzero(cand_mask)
            take = min(len(cand), len(replace_slots))
            if take:
                picks = self._pick_candidates(cand, take)
                batch[replace_slots[:take]] = picks
                # substitutions = storage fetches avoided via cached unseen
                self.substitutions += int(
                    np.count_nonzero(~cached_req[replace_slots[:take]]))
            # leftover *already-seen* slots must still be served uniquely:
            # fall back to unseen, uncached samples
            left = replace_slots[take:]
            if len(left):
                need = left[seen[requested[left]]]
                if len(need):
                    pool = np.flatnonzero(~seen & (self.status == IN_STORAGE))
                    pool = np.setdiff1d(pool, batch, assume_unique=False)
                    # deprioritize ids another job is already producing
                    # (coalescing in flight) — but only when enough
                    # clear ids remain to fill every slot, so coverage
                    # guarantees never bend for a heuristic
                    if (self.inflight is not None and len(pool)
                            and self.inflight[pool].any()):
                        clear = pool[~self.inflight[pool]]
                        if len(clear) >= len(need):
                            pool = clear
                    fill = self.rng.permutation(pool)[:len(need)]
                    batch[need] = fill

        # step 3: increment refcounts of augmented-tier hits
        aug_hits = batch[self.status[batch] == AUGMENTED]
        self.refcount[aug_hits] += 1
        hit_ids = batch[self.status[batch] != IN_STORAGE]
        self.hits += len(hit_ids)
        self.misses += B - len(hit_ids)

        # step 4: update seen bit-vector
        seen[batch] = True
        self.served[job_id] += B

        # step 5: refcount-threshold eviction of augmented samples
        thr = self.n_jobs if evict_threshold is None else evict_threshold
        evict = aug_hits[self.refcount[aug_hits] >= thr]
        if len(evict):
            self.mark_evicted(evict)
        return batch, evict

    def _pick_candidates(self, cand: np.ndarray, take: int) -> np.ndarray:
        """Draw ``take`` substitution picks from ``cand``, in-flight
        ids last: a cached candidate whose (re)production is being
        coalesced right now is drawn only once the clear candidates run
        out.  With no in-flight overlap (the common case, and always
        when coalescing is off) this is exactly one :meth:`_draw` on
        the full candidate set — byte-identical to the mask-free
        sampler."""
        infl = self.inflight
        if infl is not None and len(cand) and infl[cand].any():
            busy_mask = infl[cand]
            groups = (cand[~busy_mask], cand[busy_mask])
            picks = []
            left = take
            for group in groups:
                n = min(left, len(group))
                if n:
                    picks.append(self._draw(group, n))
                    left -= n
            return (np.concatenate(picks) if picks
                    else np.empty(0, np.int64))
        return self._draw(cand, take)

    def _draw(self, cand: np.ndarray, take: int) -> np.ndarray:
        """Draw ``take`` picks from ``cand``.  Single-tier (residency
        None): one uniform draw, the paper's rule and the historical
        byte-identical path.  Tiered: faster-tier candidates are
        exhausted first (uniformly among themselves) — device (HBM)
        residents, then DRAM, then disk — opportunistic sampling
        prefers the fastest tier when several could fill a slot.  With
        no level-3 entries the HBM bucket is empty and the draw
        sequence is byte-identical to the two-tier rule."""
        if self.residency is None:
            return self.rng.choice(cand, size=take, replace=False)
        res = self.residency[cand]
        buckets = (cand[res >= 3], cand[(res >= 2) & (res < 3)],
                   cand[res < 2])
        picks = []
        left = take
        for bucket in buckets:
            n = min(left, len(bucket))
            if n:
                picks.append(self.rng.choice(bucket, size=n,
                                             replace=False))
                left -= n
        return np.concatenate(picks) if picks else np.empty(0, np.int64)

    # ------------------------------------------------------------------
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    # ------------------------------------------------------------------
    def checkpoint_job(self, job_id: int) -> Dict:
        """Epoch-consistent snapshot of one job's sampling state.

        Captures exactly what exactly-once-per-epoch coverage depends
        on: the seen bit-vector (bit-packed), epoch counter, and served
        count.  The shared substitution RNG and counters are recorded
        for inspection but deliberately *not* restored by
        :meth:`restore_job` — they are dataset-global, and rewinding
        them would perturb every concurrent job.
        """
        if job_id not in self.seen:
            raise KeyError(f"job {job_id} is not registered")
        return {
            "n_samples": self.n_samples,
            "seen": np.packbits(self.seen[job_id]),
            "epoch": int(self.epoch[job_id]),
            "served": int(self.served[job_id]),
            "substitutions": int(self.substitutions),
            "rng_state": self.rng.bit_generator.state,
        }

    def restore_job(self, job_id: int, snap: Dict) -> None:
        """Install a :meth:`checkpoint_job` snapshot for ``job_id`` (the
        id may differ from the one snapshotted — re-admitted jobs get a
        fresh session id)."""
        if int(snap["n_samples"]) != self.n_samples:
            raise ValueError(
                f"snapshot is for a {snap['n_samples']}-sample dataset, "
                f"this one has {self.n_samples}")
        if job_id not in self.seen:
            raise KeyError(f"job {job_id} is not registered")
        self.seen[job_id] = np.unpackbits(
            np.asarray(snap["seen"], np.uint8),
            count=self.n_samples).astype(bool)
        self.epoch[job_id] = int(snap["epoch"])
        self.served[job_id] = int(snap["served"])


def merge_residency(parts) -> np.ndarray:
    """Merge per-shard residency (or status) arrays into the global
    view the ODS substitution sampler consumes.

    Shards own disjoint key ranges (the consistent-hash ring maps every
    sample to exactly one shard), so each sample is nonzero in at most
    one shard's array and an elementwise maximum is an exact merge —
    while also being safe under transient double-residency (a key mid-
    migration reports its best tier).
    """
    arrays = [np.asarray(p) for p in parts]
    if not arrays:
        raise ValueError("merge_residency needs at least one shard array")
    out = arrays[0].copy()
    for a in arrays[1:]:
        if a.shape != out.shape:
            raise ValueError(
                f"shard array shapes differ: {a.shape} vs {out.shape}")
        np.maximum(out, a, out=out)
    return out


class EpochSampler:
    """Per-job pseudo-random epoch permutation, consumed batch by batch."""

    def __init__(self, n_samples: int, batch_size: int, seed: int):
        self.n = n_samples
        self.bs = batch_size
        self.rng = np.random.default_rng(seed)
        self._perm = self.rng.permutation(self.n)
        self._pos = 0

    def next_request(self) -> np.ndarray:
        if self._pos + self.bs > self.n:
            self._perm = self.rng.permutation(self.n)
            self._pos = 0
        out = self._perm[self._pos:self._pos + self.bs]
        self._pos += self.bs
        return out

    # ------------------------------------------------------------------
    def state_dict(self) -> Dict:
        """Full sampler position: current permutation, offset, and RNG
        state — restoring reproduces the exact upcoming request
        sequence, including every future re-permutation."""
        return {
            "n": self.n,
            "bs": self.bs,
            "perm": self._perm.copy(),
            "pos": int(self._pos),
            "rng_state": self.rng.bit_generator.state,
        }

    def load_state_dict(self, state: Dict) -> None:
        if int(state["n"]) != self.n or int(state["bs"]) != self.bs:
            raise ValueError(
                f"sampler snapshot is for n={state['n']} bs={state['bs']}"
                f", this sampler has n={self.n} bs={self.bs}")
        self._perm = np.asarray(state["perm"], dtype=self._perm.dtype).copy()
        self._pos = int(state["pos"])
        self.rng.bit_generator.state = state["rng_state"]
