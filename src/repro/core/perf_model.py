"""The DSI pipeline performance model (Seneca §5.1, Eqs. 1–9).

The model predicts DSI throughput (samples/s) for a data-parallel training
cluster given hardware parameters (Table 3), a dataset, and the cache split
``(x_E, x_D, x_A)`` across the three data forms.

Faithfulness notes:
* Equations follow the paper exactly; all evaluations are vectorized over
  the partition simplex so MDP's 1%-granularity brute force (~5k points)
  is a single numpy pass.
* The paper expresses gradient-communication overheads C_nw / C_PCIe in
  bytes "for a batch" but adds them to per-sample sizes inside Eqs. 1/3/5.
  We therefore normalize: ``c = (2(n-1)/n) * model_bytes / batch_size``
  (per-sample share of each ring all-reduce).  The paper's text assigns
  "GPUs per node" to C_nw and "nodes" to C_PCIe, which is swapped relative
  to its own definitions; we implement the physically meaningful pairing
  (nodes -> network, GPUs/node -> PCIe) and note the discrepancy here.
* NVLink special cases (§5.1): intra-node NVLink -> C_PCIe = 0; inter-node
  NVLink -> both 0.  On TPU these correspond to "ICI is not the gradient
  bottleneck" (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

import numpy as np

GB = 1e9
MB = 1e6
KB = 1e3
Gbit = 1e9 / 8

#: local-SSD read-bandwidth prior for spill tiers whose profile carries
#: no measured ``b_disk`` (telemetry calibration replaces it live)
DEFAULT_DISK_BW = 1.5 * GB

#: device-memory serve-bandwidth prior for HBM tiers with no measured
#: ``b_hbm`` — conservatively the host→device link rate until the "h2d"
#: telemetry channel calibrates it (an HBM hit costs no transfer at all,
#: but the *fill* path that earned residency ran at this rate)
DEFAULT_HBM_BW = 100 * GB


@dataclass(frozen=True)
class HardwareProfile:
    """Per-node performance (Table 3 / Table 5)."""
    name: str
    t_gpu: float              # GPU ingestion (samples/s/node)
    t_da: float               # CPU decode+augment (samples/s/node)
    t_a: float                # CPU augment-only (samples/s/node)
    b_nic: float              # network bandwidth (B/s/node)
    b_pcie: float             # PCIe bandwidth (B/s/node)
    b_cache: float            # remote cache service bandwidth (B/s)
    b_storage: float          # remote storage bandwidth (B/s)
    s_cache: float            # cache capacity (bytes)
    n_nodes: int = 1
    gpus_per_node: int = 4
    nvlink_intra: bool = False
    nvlink_inter: bool = False
    # SSD spill tier (form×tier MDP): 0 disables the disk level
    b_disk: float = 0.0       # local disk read bandwidth (B/s)
    s_disk: float = 0.0       # disk spill capacity (bytes)
    # device-resident (HBM) tier: 0 disables the device level
    b_hbm: float = 0.0        # device-tier serve bandwidth (B/s)
    s_hbm: float = 0.0        # device cache capacity (bytes)


@dataclass(frozen=True)
class DatasetProfile:
    """Dataset parameters (Table 6) with per-form byte sizes.

    The paper's single inflation factor M=5.12 (Table 5) is precisely the
    fp32 *augmented* tensor over the encoded size for ImageNet-1K
    (224x224x3x4B = 602KB / 114.62KB = 5.25 ~ 5.12).  The *decoded* form in
    a torchvision pipeline is the uint8 image before ToTensor/Normalize
    (256x256x3 = 196KB).  Modelling each form with its true byte size
    (rather than one M for both) recovers Table 6's marquee splits — e.g.
    OpenImages/Azure "5-95-0" is exactly the minimal decoded-covering split
    (1.9M x 196KB / 400GB = 0.93).  See EXPERIMENTS.md §MDP.
    """
    name: str
    n_total: int                       # samples
    s_data: float                      # encoded sample size (bytes)
    decoded_bytes: float = 256 * 256 * 3            # uint8 decode
    augmented_bytes: float = 224 * 224 * 3 * 4      # fp32 augmented
    gpu_bytes: float = 224 * 224 * 3 * 4            # fp32 over PCIe
    inflation: float = 0.0             # legacy M; 0 -> derived per form

    @property
    def m_gpu(self) -> float:
        return (self.inflation or self.gpu_bytes / self.s_data)


@dataclass(frozen=True)
class JobProfile:
    """Training-job parameters entering the C_nw / C_PCIe terms."""
    model_bytes: float = 100 * MB
    batch_size: int = 256


@dataclass(frozen=True)
class DSIThroughput:
    dsi_a: float
    dsi_d: float
    dsi_e: float
    dsi_s: float
    n_a: float
    n_d: float
    n_e: float
    n_storage: float
    overall: float
    bottleneck: str


def _comm_overheads(hw: HardwareProfile, job: JobProfile) -> Tuple[float, float]:
    """Per-sample gradient communication overhead bytes (c_nw, c_pcie)."""
    def ring(n: int) -> float:
        return 2.0 * (n - 1) / n * job.model_bytes if n > 1 else 0.0
    c_nw = ring(hw.n_nodes) / job.batch_size
    c_pcie = ring(hw.gpus_per_node) / job.batch_size
    if hw.nvlink_intra or hw.nvlink_inter:
        c_pcie = 0.0
    if hw.nvlink_inter:
        c_nw = 0.0
    return c_nw, c_pcie


def dsi_throughput(hw: HardwareProfile, ds: DatasetProfile, job: JobProfile,
                   x_e, x_d, x_a) -> DSIThroughput:
    """Evaluate Eqs. 1–9. x_* may be scalars or broadcastable arrays."""
    x_e = np.asarray(x_e, np.float64)
    x_d = np.asarray(x_d, np.float64)
    x_a = np.asarray(x_a, np.float64)
    n = hw.n_nodes
    S = ds.s_data
    a_b, d_b, g_b = ds.augmented_bytes, ds.decoded_bytes, ds.gpu_bytes
    if ds.inflation:                   # legacy single-M mode
        a_b = d_b = g_b = ds.inflation * S
    c_nw, c_pcie = _comm_overheads(hw, job)

    # Eq. 1 — augmented data in cache
    terms_a = np.stack(np.broadcast_arrays(
        hw.b_cache / a_b + 0 * x_a,
        n * hw.b_nic / (a_b + c_nw) + 0 * x_a,
        n * hw.b_pcie / (g_b + c_pcie) + 0 * x_a,
        np.asarray(n * hw.t_gpu, np.float64) + 0 * x_a))
    dsi_a = terms_a.min(axis=0)

    # Eq. 2
    n_a = np.minimum(ds.n_total, x_a * hw.s_cache / a_b)

    # Eq. 3 — decoded data in cache (CPU applies augmentations)
    terms_d = np.stack(np.broadcast_arrays(
        hw.b_cache / d_b + 0 * x_d,
        n * hw.b_nic / (d_b + c_nw) + 0 * x_d,
        np.asarray(n * hw.t_a, np.float64) + 0 * x_d,
        n * hw.b_pcie / (g_b + c_pcie) + 0 * x_d,
        np.asarray(n * hw.t_gpu, np.float64) + 0 * x_d))
    dsi_d = terms_d.min(axis=0)

    # Eq. 4
    n_d = np.minimum(ds.n_total - n_a, x_d * hw.s_cache / d_b)

    # Eq. 5 — encoded data in cache (CPU decodes + augments)
    terms_e = np.stack(np.broadcast_arrays(
        hw.b_cache / S + 0 * x_e,
        n * hw.b_nic / (S + c_nw) + 0 * x_e,
        np.asarray(n * hw.t_da, np.float64) + 0 * x_e,
        n * hw.b_pcie / (g_b + c_pcie) + 0 * x_e,
        np.asarray(n * hw.t_gpu, np.float64) + 0 * x_e))
    dsi_e = terms_e.min(axis=0)

    # Eq. 6
    n_e = np.minimum(ds.n_total - (n_a + n_d), x_e * hw.s_cache / S)

    # Eq. 7 — storage
    dsi_s = np.minimum(dsi_e, hw.b_storage / S)

    # Eq. 8
    n_storage = np.maximum(ds.n_total - n_a - n_d - n_e, 0.0)

    # Eq. 9
    overall = (n_a * dsi_a + n_d * dsi_d + n_e * dsi_e
               + n_storage * dsi_s) / ds.n_total

    names_a = ("cache_bw", "nic", "pcie", "gpu")
    names_d = ("cache_bw", "nic", "cpu_augment", "pcie", "gpu")
    names_e = ("cache_bw", "nic", "cpu_decode_augment", "pcie", "gpu")
    if overall.ndim == 0:
        # dominant (highest-weight) access class decides the bottleneck label
        weights = np.array([n_a * dsi_a, n_d * dsi_d, n_e * dsi_e,
                            n_storage * dsi_s])
        cls = int(np.argmax(weights))
        bn = [names_a[int(terms_a.argmin(0))],
              names_d[int(terms_d.argmin(0))],
              names_e[int(terms_e.argmin(0))],
              "storage_bw" if dsi_s < dsi_e else
              names_e[int(terms_e.argmin(0))]][cls]
    else:
        bn = "vectorized"
    return DSIThroughput(
        dsi_a=dsi_a, dsi_d=dsi_d, dsi_e=dsi_e, dsi_s=dsi_s,
        n_a=n_a, n_d=n_d, n_e=n_e, n_storage=n_storage,
        overall=overall, bottleneck=bn)


# ---------------------------------------------------------------------------
# Form × tier model (DRAM level + SSD spill level)
# ---------------------------------------------------------------------------

def _form_rates(hw: HardwareProfile, ds: DatasetProfile, job: JobProfile,
                b_serve: float) -> Tuple[float, float, float, float]:
    """Per-form serve rates (Eqs. 1/3/5/7) with the cache-bandwidth term
    replaced by ``b_serve`` — the per-tier generalization: a DRAM hit is
    served at ``b_cache``, a disk hit at ``b_disk``, everything else in
    the equations (NIC, CPU, PCIe, GPU) is tier-independent."""
    n = hw.n_nodes
    S = ds.s_data
    a_b, d_b, g_b = ds.augmented_bytes, ds.decoded_bytes, ds.gpu_bytes
    if ds.inflation:
        a_b = d_b = g_b = ds.inflation * S
    c_nw, c_pcie = _comm_overheads(hw, job)
    dsi_a = min(b_serve / a_b, n * hw.b_nic / (a_b + c_nw),
                n * hw.b_pcie / (g_b + c_pcie), n * hw.t_gpu)
    dsi_d = min(b_serve / d_b, n * hw.b_nic / (d_b + c_nw), n * hw.t_a,
                n * hw.b_pcie / (g_b + c_pcie), n * hw.t_gpu)
    dsi_e = min(b_serve / S, n * hw.b_nic / (S + c_nw), n * hw.t_da,
                n * hw.b_pcie / (g_b + c_pcie), n * hw.t_gpu)
    dsi_s = min(dsi_e, hw.b_storage / S)
    return dsi_a, dsi_d, dsi_e, dsi_s


def dsi_throughput_tiered(hw: HardwareProfile, ds: DatasetProfile,
                          job: JobProfile, dram_split, disk_split,
                          hbm_split=None):
    """Overall DSI throughput with a two- or three-level cache.

    ``dram_split`` partitions ``hw.s_cache``, ``disk_split`` partitions
    ``hw.s_disk`` and ``hbm_split`` (default: ``dram_split``'s
    geometry) partitions ``hw.s_hbm`` across the three forms; each may
    be a scalar triple or broadcastable arrays (the MDP solver fixes
    two levels and sweeps the third).  Coverage is greedy
    most-processed first within each level (Eqs. 2/4/6), faster levels
    covering first — HBM, then DRAM, then the disk level over what DRAM
    left over; per-form DRAM/disk serve rates come from
    :func:`_form_rates` at ``b_cache`` vs ``b_disk``.  A device-tier
    hit is already accelerator-resident and device kernels handle any
    remaining processing (fused decode+augment), so its rate skips the
    NIC/CPU/PCIe terms entirely: ``min(b_hbm / bytes_f, n * t_gpu)``.
    With ``b_hbm * s_hbm == 0`` the computation is *bit-identical* to
    the two-level model (regression-pinned), and with
    ``b_disk * s_disk == 0`` too it reduces exactly to Eq. 9.
    """
    x_e, x_d, x_a = (np.asarray(v, np.float64) for v in dram_split)
    y_e, y_d, y_a = (np.asarray(v, np.float64) for v in disk_split)
    S = ds.s_data
    a_b, d_b = ds.augmented_bytes, ds.decoded_bytes
    if ds.inflation:
        a_b = d_b = ds.inflation * S
    da1, dd1, de1, dsi_s = _form_rates(hw, ds, job, hw.b_cache)
    s_disk = hw.s_disk if hw.b_disk > 0 else 0.0
    if s_disk > 0:
        da2, dd2, de2, _ = _form_rates(hw, ds, job, hw.b_disk)
    else:
        da2 = dd2 = de2 = 0.0
    N = float(ds.n_total)
    remaining = N + 0.0 * (x_a + y_a)          # broadcast shape
    hbm = 0.0
    s_hbm = hw.s_hbm if hw.b_hbm > 0 else 0.0
    if s_hbm > 0:
        zs = hbm_split if hbm_split is not None else (x_e, x_d, x_a)
        z_e, z_d, z_a = (np.asarray(v, np.float64) for v in zs)
        n = hw.n_nodes
        da0 = min(hw.b_hbm / a_b, n * hw.t_gpu)
        dd0 = min(hw.b_hbm / d_b, n * hw.t_gpu)
        de0 = min(hw.b_hbm / S, n * hw.t_gpu)
        remaining = remaining + 0.0 * z_a
        n_a0 = np.minimum(remaining, z_a * s_hbm / a_b)
        remaining = remaining - n_a0
        n_d0 = np.minimum(remaining, z_d * s_hbm / d_b)
        remaining = remaining - n_d0
        n_e0 = np.minimum(remaining, z_e * s_hbm / S)
        remaining = remaining - n_e0
        hbm = n_a0 * da0 + n_d0 * dd0 + n_e0 * de0
    n_a1 = np.minimum(remaining, x_a * hw.s_cache / a_b)
    remaining = remaining - n_a1
    n_d1 = np.minimum(remaining, x_d * hw.s_cache / d_b)
    remaining = remaining - n_d1
    n_e1 = np.minimum(remaining, x_e * hw.s_cache / S)
    remaining = remaining - n_e1
    n_a2 = np.minimum(remaining, y_a * s_disk / a_b)
    remaining = remaining - n_a2
    n_d2 = np.minimum(remaining, y_d * s_disk / d_b)
    remaining = remaining - n_d2
    n_e2 = np.minimum(remaining, y_e * s_disk / S)
    remaining = remaining - n_e2
    overall = (hbm
               + n_a1 * da1 + n_d1 * dd1 + n_e1 * de1
               + n_a2 * da2 + n_d2 * dd2 + n_e2 * de2
               + np.maximum(remaining, 0.0) * dsi_s) / N
    return overall


# ---------------------------------------------------------------------------
# Telemetry calibration
# ---------------------------------------------------------------------------

#: HardwareProfile fields a telemetry snapshot can override.
CALIBRATABLE = ("t_da", "t_a", "b_storage", "b_cache", "b_disk", "b_hbm")


def calibrate(hw: HardwareProfile, telemetry,
              min_samples: int = 32) -> HardwareProfile:
    """Override ``hw``'s measured rates from observed telemetry.

    ``telemetry`` is anything exposing the :data:`CALIBRATABLE` attributes
    (samples/s for CPU rates, bytes/s for bandwidths; ``None`` = no
    signal) plus a ``counts`` mapping of observation counts per field —
    i.e. a :class:`repro.api.telemetry.TelemetrySnapshot`.  A field is
    only overridden once it has ``min_samples`` observations, so a cold
    server keeps the static Table-3 profile and calibration phases in
    gradually.  Returns ``hw`` itself when nothing qualifies, making
    "did calibration change anything" an identity check.
    """
    counts = getattr(telemetry, "counts", {}) or {}
    overrides = {}
    for name in CALIBRATABLE:
        value = getattr(telemetry, name, None)
        if value is None or not np.isfinite(value) or value <= 0:
            continue
        if counts.get(name, 0) < min_samples:
            continue
        overrides[name] = float(value)
    if not overrides:
        return hw
    base = hw.name.removesuffix("+calibrated")
    return replace(hw, name=f"{base}+calibrated", **overrides)


# ---------------------------------------------------------------------------
# Paper profiles (Tables 4, 5, 6)
# ---------------------------------------------------------------------------

IN_HOUSE = HardwareProfile(
    name="in-house", t_gpu=4550, t_da=2132, t_a=4050,
    b_nic=10 * Gbit, b_pcie=32 * GB, b_cache=10 * Gbit,
    b_storage=500 * MB, s_cache=64 * GB, n_nodes=1, gpus_per_node=2)

IN_HOUSE_2X = replace(IN_HOUSE, name="2x-in-house", n_nodes=2)

AWS_P3 = HardwareProfile(
    name="aws-p3.8xlarge", t_gpu=9989, t_da=3432, t_a=6520,
    b_nic=10 * Gbit, b_pcie=32 * GB, b_cache=10 * Gbit,
    b_storage=256 * MB, s_cache=64 * GB, n_nodes=1, gpus_per_node=4,
    nvlink_intra=True)

AZURE_NC96 = HardwareProfile(
    name="azure-nc96ads", t_gpu=14301, t_da=9783, t_a=12930,
    b_nic=80 * Gbit, b_pcie=64 * GB, b_cache=30 * Gbit,
    b_storage=250 * MB, s_cache=64 * GB, n_nodes=1, gpus_per_node=4,
    nvlink_intra=True)

AZURE_2X = replace(AZURE_NC96, name="2x-azure", n_nodes=2)

VALIDATION_PROFILES = (IN_HOUSE, IN_HOUSE_2X, AWS_P3, AZURE_NC96)

# Evaluation caches (§7): in-house 115GB, AWS/Azure 400GB remote cache.
EVAL_PROFILES = (
    replace(IN_HOUSE, s_cache=115 * GB),
    replace(IN_HOUSE_2X, s_cache=115 * GB),
    replace(AWS_P3, s_cache=400 * GB),
    replace(AZURE_NC96, s_cache=400 * GB),
    replace(AZURE_2X, s_cache=400 * GB),
)

IMAGENET_1K = DatasetProfile("imagenet-1k", 1_300_000, 114.62 * KB)
OPENIMAGES = DatasetProfile("openimages-v7", 1_900_000, 315.84 * KB)
IMAGENET_22K = DatasetProfile("imagenet-22k", 14_000_000, 91.39 * KB)
# Table-5-faithful single-M variant (fp32 tensors everywhere) used by the
# Fig. 8 model-validation benchmark:
IMAGENET_1K_M512 = DatasetProfile("imagenet-1k-m5.12", 1_300_000,
                                  114.62 * KB, inflation=5.12)

DATASETS = (IMAGENET_1K, OPENIMAGES, IMAGENET_22K)


def tpu_profile(*, t_tpu_samples: float, n_hosts: int,
                host_cpu_da: float = 8000.0, host_cpu_a: float = 15000.0,
                dcn_bw: float = 25 * GB, pcie_bw: float = 32 * GB,
                cache_bw: float = 50 * GB, storage_bw: float = 2 * GB,
                cache_bytes: float = 256 * GB) -> HardwareProfile:
    """TPU-pod hardware profile: T_GPU becomes the per-host TPU ingestion
    rate derived from the compiled-step roofline (DESIGN.md §2)."""
    return HardwareProfile(
        name=f"tpu-pod-{n_hosts}h", t_gpu=t_tpu_samples, t_da=host_cpu_da,
        t_a=host_cpu_a, b_nic=dcn_bw, b_pcie=pcie_bw, b_cache=cache_bw,
        b_storage=storage_bw, s_cache=cache_bytes, n_nodes=n_hosts,
        gpus_per_node=4, nvlink_intra=True)
