"""Model-Driven Partitioning (Seneca §5.1 + §5.3).

Brute-force search over the (x_E, x_D, x_A) simplex at 1% granularity
(5151 points), fully vectorized through the performance model — one numpy
pass, well under the paper's "<1s" budget.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.perf_model import (DatasetProfile, HardwareProfile,
                                   JobProfile, dsi_throughput)


@dataclass(frozen=True)
class Partition:
    x_e: float
    x_d: float
    x_a: float
    throughput: float          # predicted samples/s

    @property
    def label(self) -> str:
        return (f"{round(self.x_e * 100)}-{round(self.x_d * 100)}-"
                f"{round(self.x_a * 100)}")

    def bytes_split(self, cache_bytes: float) -> Tuple[float, float, float]:
        return (self.x_e * cache_bytes, self.x_d * cache_bytes,
                self.x_a * cache_bytes)


def simplex_grid(step: float = 0.01) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All (x_e, x_d, x_a) with x_e + x_d + x_a = 1 at ``step`` granularity."""
    n = int(round(1.0 / step))
    e, d = np.meshgrid(np.arange(n + 1), np.arange(n + 1), indexing="ij")
    keep = (e + d) <= n
    e, d = e[keep], d[keep]
    a = n - e - d
    return e / n, d / n, a / n


def optimize(hw: HardwareProfile, ds: DatasetProfile,
             job: Optional[JobProfile] = None,
             step: float = 0.01) -> Partition:
    """MDP: return the optimal cache split for (hardware, dataset, job)."""
    job = job or JobProfile()
    xe, xd, xa = simplex_grid(step)
    out = dsi_throughput(hw, ds, job, xe, xd, xa)
    best = int(np.argmax(out.overall))
    return Partition(float(xe[best]), float(xd[best]), float(xa[best]),
                     float(out.overall[best]))


def sweep(hw: HardwareProfile, ds: DatasetProfile,
          job: Optional[JobProfile] = None, step: float = 0.01):
    """Full grid (for benchmarks / plots): (xe, xd, xa, throughput)."""
    job = job or JobProfile()
    xe, xd, xa = simplex_grid(step)
    out = dsi_throughput(hw, ds, job, xe, xd, xa)
    return xe, xd, xa, out.overall
