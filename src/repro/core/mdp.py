"""Model-Driven Partitioning (Seneca §5.1 + §5.3).

Brute-force search over the (x_E, x_D, x_A) simplex at 1% granularity
(5151 points), fully vectorized through the performance model — one numpy
pass, well under the paper's "<1s" budget.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import numpy as np

from repro.core.perf_model import (DatasetProfile, HardwareProfile,
                                   JobProfile, dsi_throughput,
                                   dsi_throughput_tiered)


@dataclass(frozen=True)
class Partition:
    x_e: float
    x_d: float
    x_a: float
    throughput: float          # predicted samples/s

    @property
    def label(self) -> str:
        return (f"{round(self.x_e * 100)}-{round(self.x_d * 100)}-"
                f"{round(self.x_a * 100)}")

    def bytes_split(self, cache_bytes: float) -> Tuple[float, float, float]:
        return (self.x_e * cache_bytes, self.x_d * cache_bytes,
                self.x_a * cache_bytes)


def simplex_grid(step: float = 0.01) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All (x_e, x_d, x_a) with x_e + x_d + x_a = 1 at ``step`` granularity."""
    n = int(round(1.0 / step))
    e, d = np.meshgrid(np.arange(n + 1), np.arange(n + 1), indexing="ij")
    keep = (e + d) <= n
    e, d = e[keep], d[keep]
    a = n - e - d
    return e / n, d / n, a / n


# grid construction dominates a re-solve once dsi_throughput is one
# vectorized pass; share grids across solver instances (read-only)
_GRIDS: dict = {}


def _grid_cached(step: float) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    grid = _GRIDS.get(step)
    if grid is None:
        grid = simplex_grid(step)
        for arr in grid:
            arr.setflags(write=False)
        _GRIDS[step] = grid
    return grid


def _solve_on_grid(hw: HardwareProfile, ds: DatasetProfile,
                   job: JobProfile, grid) -> Partition:
    """One vectorized model pass over ``grid`` -> best Partition (shared
    by optimize() and IncrementalSolver so the construction-time solve
    and the controller's re-solves can never diverge)."""
    xe, xd, xa = grid
    out = dsi_throughput(hw, ds, job, xe, xd, xa)
    best = int(np.argmax(out.overall))
    return Partition(float(xe[best]), float(xd[best]), float(xa[best]),
                     float(out.overall[best]))


def optimize(hw: HardwareProfile, ds: DatasetProfile,
             job: Optional[JobProfile] = None,
             step: float = 0.01) -> Partition:
    """MDP: return the optimal cache split for (hardware, dataset, job)."""
    return _solve_on_grid(hw, ds, job or JobProfile(), _grid_cached(step))


# ---------------------------------------------------------------------------
# Form × tier MDP (DRAM split + disk-spill split)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TieredPartition:
    """One split per cache level: ``dram`` partitions ``s_cache``,
    ``disk`` partitions ``s_disk``, and (when a device tier is
    configured) ``hbm`` partitions ``s_hbm``; ``throughput`` is the
    combined multi-level model prediction (member Partitions carry it
    too).  ``hbm`` trails with a ``None`` default so existing
    two-level positional construction keeps working."""
    dram: Partition
    disk: Partition
    throughput: float
    hbm: Optional[Partition] = None

    @property
    def label(self) -> str:
        if self.hbm is not None:
            return f"{self.hbm.label}|{self.dram.label}|{self.disk.label}"
        return f"{self.dram.label}|{self.disk.label}"


def _solve_level_on_grid(hw, ds, job, grid, fixed, level: str,
                         fixed_hbm=None) -> Partition:
    """Sweep one level's simplex with the other level(s) fixed — a
    single vectorized tiered model pass.  ``fixed_hbm`` pins the device
    level while sweeping dram/disk; ``level == "hbm"`` sweeps the
    device level with ``fixed`` = (dram_split, disk_split)."""
    xe, xd, xa = grid
    if level == "dram":
        overall = dsi_throughput_tiered(hw, ds, job, (xe, xd, xa), fixed,
                                        fixed_hbm)
    elif level == "disk":
        overall = dsi_throughput_tiered(hw, ds, job, fixed, (xe, xd, xa),
                                        fixed_hbm)
    else:                                  # "hbm"
        dram_fixed, disk_fixed = fixed
        overall = dsi_throughput_tiered(hw, ds, job, dram_fixed,
                                        disk_fixed, (xe, xd, xa))
    best = int(np.argmax(overall))
    return Partition(float(xe[best]), float(xd[best]), float(xa[best]),
                     float(overall[best]))


def optimize_tiered(hw: HardwareProfile, ds: DatasetProfile,
                    job: Optional[JobProfile] = None, step: float = 0.01,
                    sweeps: int = 2) -> TieredPartition:
    """Form×tier MDP: coordinate descent over up to three simplexes.

    A joint 1%-grid over multiple levels is combinatorial (~26M points
    for two, ~10^11 for three); instead each sweep fixes the other
    level(s) and brute-forces one (vectorized 5151-point passes).  The
    objective is monotone under each conditional argmax, so a couple of
    sweeps reach a coordinate-wise optimum — in practice the first pass
    per level already lands it, because faster levels' greedy coverage
    is solved first and slower levels only see the leftovers.  With no
    disk tier configured the result degenerates to :func:`optimize`'s
    split with an all-encoded disk label placeholder; with no device
    tier ``hbm`` stays ``None`` and the solve is exactly the two-level
    descent.
    """
    job = job or JobProfile()
    grid = _grid_cached(step)
    dram = _solve_on_grid(hw, ds, job, grid)      # one-level warm start
    disk = Partition(1.0, 0.0, 0.0, dram.throughput)
    has_hbm = hw.b_hbm > 0 and hw.s_hbm > 0
    has_disk = hw.b_disk > 0 and hw.s_disk > 0
    if not has_disk and not has_hbm:
        return TieredPartition(dram, disk, dram.throughput)
    hbm = Partition(0.0, 0.0, 1.0, dram.throughput) if has_hbm else None
    for _ in range(max(int(sweeps), 1)):
        hbm_fixed = (hbm.x_e, hbm.x_d, hbm.x_a) if has_hbm else None
        if has_hbm:
            # fastest level first: device coverage shapes what the
            # lower levels are left to cover
            hbm = _solve_level_on_grid(
                hw, ds, job, grid,
                ((dram.x_e, dram.x_d, dram.x_a),
                 (disk.x_e, disk.x_d, disk.x_a)), "hbm")
            hbm_fixed = (hbm.x_e, hbm.x_d, hbm.x_a)
        if has_disk:
            disk = _solve_level_on_grid(
                hw, ds, job, grid,
                (dram.x_e, dram.x_d, dram.x_a), "disk", hbm_fixed)
        dram = _solve_level_on_grid(
            hw, ds, job, grid,
            (disk.x_e, disk.x_d, disk.x_a), "dram", hbm_fixed)
    thr = dram.throughput
    return TieredPartition(replace_throughput(dram, thr),
                           replace_throughput(disk, thr), thr,
                           replace_throughput(hbm, thr) if hbm else None)


def replace_throughput(p: Partition, thr: float) -> Partition:
    return Partition(p.x_e, p.x_d, p.x_a, thr)


# ---------------------------------------------------------------------------
# Per-shard solves (the sharded data plane, src/repro/service/)
# ---------------------------------------------------------------------------

def shard_view(hw: HardwareProfile, ds: DatasetProfile, n_shards: int
               ) -> Tuple[HardwareProfile, DatasetProfile]:
    """One shard's view of (hardware, dataset) for a shard-local solve.

    The consistent-hash ring divides both the capacity (each shard owns
    1/N of the cache and spill budget) and the key space (each shard
    owns ~1/N of the samples), so capacity fields and the population
    scale down together — the coverage ratios the model's miss-rate
    terms consume are preserved.  Bandwidth/rate fields stay whole:
    each request still sees the full channel.
    """
    n = max(int(n_shards), 1)
    if n == 1:
        return hw, ds
    return (replace(hw, s_cache=hw.s_cache / n, s_disk=hw.s_disk / n,
                    s_hbm=hw.s_hbm / n),
            replace(ds, n_total=max(int(np.ceil(ds.n_total / n)), 1)))


def optimize_shard(hw: HardwareProfile, ds: DatasetProfile,
                   job: Optional[JobProfile] = None, n_shards: int = 1,
                   step: float = 0.01, tiered: bool = False):
    """Form(×tier) MDP for one shard of an N-way sharded cache: the
    global solve re-run on the shard's 1/N view.  Returns a
    :class:`Partition` (or :class:`TieredPartition` with ``tiered``)."""
    shw, sds = shard_view(hw, ds, n_shards)
    if tiered:
        return optimize_tiered(shw, sds, job, step)
    return optimize(shw, sds, job, step)


class IncrementalSolver:
    """Re-solvable MDP for one (dataset, job): the simplex grid is built
    once and every ``solve(hw)`` is a single vectorized model pass, so the
    RepartitionController can re-run MDP per calibration tick well under
    the paper's <1 s budget.
    """

    def __init__(self, ds: DatasetProfile, job: Optional[JobProfile] = None,
                 step: float = 0.01):
        self.ds = ds
        self.job = job or JobProfile()
        self.step = step
        self._grid = _grid_cached(step)
        self.n_solves = 0

    def solve(self, hw: HardwareProfile) -> Partition:
        """Best split for ``hw`` (typically a calibrated profile)."""
        self.n_solves += 1
        return _solve_on_grid(hw, self.ds, self.job, self._grid)

    def predict(self, hw: HardwareProfile,
                split: Tuple[float, float, float]) -> float:
        """Model-predicted throughput of one concrete split under ``hw``
        (the drift / hysteresis comparisons in the controller)."""
        out = dsi_throughput(hw, self.ds, self.job, *split)
        return float(out.overall)

    def solve_tiered(self, hw: HardwareProfile) -> TieredPartition:
        """Form×tier re-solve (shares the cached grid; two coordinate
        sweeps, each one vectorized pass)."""
        self.n_solves += 1
        return optimize_tiered(hw, self.ds, self.job, self.step)

    def predict_tiered(self, hw: HardwareProfile,
                       dram_split: Tuple[float, float, float],
                       disk_split: Tuple[float, float, float],
                       hbm_split: Optional[Tuple[float, float, float]]
                       = None) -> float:
        """Tiered model prediction for one concrete (dram, disk[, hbm])
        split tuple."""
        return float(dsi_throughput_tiered(hw, self.ds, self.job,
                                           dram_split, disk_split,
                                           hbm_split))


def sweep(hw: HardwareProfile, ds: DatasetProfile,
          job: Optional[JobProfile] = None, step: float = 0.01):
    """Full grid (for benchmarks / plots): (xe, xd, xa, throughput)."""
    job = job or JobProfile()
    xe, xd, xa = simplex_grid(step)
    out = dsi_throughput(hw, ds, job, xe, xd, xa)
    return xe, xd, xa, out.overall
