"""Jittable ODS substitution (TPU-native adaptation, DESIGN.md §2).

The paper's ODS walks the batch sample-by-sample.  On a TPU host we want the
substitution decision itself to be a fused vectorized program so it can run
inside the input pipeline's jitted prologue (and, at scale, on-device over a
sharded metadata table).  This module implements one batch-substitution step
as a pure function over flat arrays with ``jax.lax`` primitives only.

Semantic difference vs :mod:`repro.core.ods` (documented, tested): candidate
selection uses a priority argsort seeded by a fold-in PRNG instead of
``Generator.choice``, so the two implementations agree on *which class* of
sample fills each slot (the invariants), not on the specific random pick.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ODSJaxState(NamedTuple):
    status: jax.Array          # uint8[N]  0=storage 1=enc 2=dec 3=aug
    refcount: jax.Array        # int32[N]
    seen: jax.Array            # bool[N]   (one job's bit-vector)
    served: jax.Array          # int32 scalar


def create(n: int) -> ODSJaxState:
    return ODSJaxState(
        status=jnp.zeros(n, jnp.uint8),
        refcount=jnp.zeros(n, jnp.int32),
        seen=jnp.zeros(n, bool),
        served=jnp.zeros((), jnp.int32))


def _substitute_core(state: ODSJaxState, requested: jax.Array,
                     rng: jax.Array, n_jobs: int, residency,
                     inflight=None
                     ) -> Tuple[ODSJaxState, jax.Array, jax.Array]:
    """One ODS batch step; the single body behind all public variants
    (the rollover / direct-hit / fill / refcount bookkeeping must never
    diverge between them — only candidate *scoring* differs).

    ``residency`` is ``None`` (single-tier: cached-unseen 2 > uncached-
    unseen 1) or uint8[N] tier levels 0 storage / 1 disk / 2 DRAM /
    3 HBM (tiered: HBM-unseen 4 > DRAM-unseen 3 > disk-unseen 2 >
    uncached-unseen 1; with no level-3 entries the ranks reduce exactly
    to the two-tier rule) — a trace-time constant, so each variant
    compiles once.

    ``inflight`` is ``None`` (no coalescing table, the historical
    scoring — rank values byte-identical to before the knob existed) or
    bool[N] in-flight productions: scores are doubled and in-flight
    candidates pay a −1 penalty, so within every class the clear ids
    outrank the in-flight ones while the class order itself (tier
    beats tier, cached beats uncached) is preserved exactly.
    """
    N = state.status.shape[0]
    B = requested.shape[0]

    # epoch rollover when fewer than B unseen remain
    roll = (N - state.served) < B
    seen = jnp.where(roll, jnp.zeros_like(state.seen), state.seen)
    served = jnp.where(roll, 0, state.served)

    cached = state.status != 0
    direct = cached[requested] & ~seen[requested]

    # priority of every sample as a substitute; seen and in-batch
    # samples are excluded
    in_batch_direct = jnp.zeros(N, bool).at[requested].max(direct)
    free = ~seen & ~in_batch_direct
    if residency is None:
        score = jnp.where(free & cached, 2, 0)
    else:
        hbm = residency >= 3
        dram = residency >= 2
        score = jnp.where(free & cached & hbm, 4, 0)
        score = jnp.where(free & cached & dram & ~hbm,
                          jnp.maximum(score, 3), score)
        score = jnp.where(free & cached & ~dram, jnp.maximum(score, 2),
                          score)
    score = jnp.where(free & ~cached, jnp.maximum(score, 1), score)
    if inflight is not None:
        # double the class scores, then a −1 in-flight penalty: clear
        # ids win within each class, classes never interleave
        score = jnp.where(inflight & (score > 0), 2 * score - 1,
                          2 * score)
    noise = jax.random.uniform(rng, (N,))
    rank = score.astype(jnp.float32) + noise          # in (0, max_score+1)
    order = jnp.argsort(-rank)                         # best candidates first

    take_slot = jnp.cumsum(~direct) - 1                # per-slot index
    batch = jnp.where(direct, requested, order[jnp.clip(take_slot, 0, N - 1)])

    # bookkeeping
    aug_hit = state.status[batch] == 3
    refcount = state.refcount.at[batch].add(aug_hit.astype(jnp.int32))
    evict_ids = jnp.where(aug_hit & (refcount[batch] >= n_jobs), batch, N)
    evict_mask = jnp.zeros(N + 1, bool).at[evict_ids].set(True)[:N]
    status = jnp.where(evict_mask, 0, state.status).astype(jnp.uint8)
    refcount = jnp.where(evict_mask, 0, refcount)
    seen = seen.at[batch].set(True)
    return (ODSJaxState(status, refcount, seen, served + B), batch,
            evict_mask)


def substitute(state: ODSJaxState, requested: jax.Array, rng: jax.Array,
               n_jobs: int) -> Tuple[ODSJaxState, jax.Array, jax.Array]:
    """One ODS batch step. Returns (state', batch ids, evict mask[N]).

    Fully shape-static: selection is done by ranking all N samples by
    (serveability, random key) and taking the top slots needed.
    """
    return _substitute_core(state, requested, rng, n_jobs, None)


substitute_jit = jax.jit(substitute, static_argnames=("n_jobs",))


def substitute_tiered(state: ODSJaxState, requested: jax.Array,
                      rng: jax.Array, n_jobs: int, residency: jax.Array
                      ) -> Tuple[ODSJaxState, jax.Array, jax.Array]:
    """Residency-aware ODS batch step (two-level cache twin of
    :func:`substitute`): DRAM-resident cached-unseen samples outrank
    disk-resident ones, which outrank unseen storage fetches — the same
    preference order the NumPy ``_pick_candidates`` applies."""
    return _substitute_core(state, requested, rng, n_jobs, residency)


substitute_tiered_jit = jax.jit(substitute_tiered,
                                static_argnames=("n_jobs",))


def substitute_inflight(state: ODSJaxState, requested: jax.Array,
                        rng: jax.Array, n_jobs: int, inflight: jax.Array
                        ) -> Tuple[ODSJaxState, jax.Array, jax.Array]:
    """Coalescing-aware ODS batch step: like :func:`substitute` but
    candidates whose production is in flight (bool[N] mask from the
    single-flight table) rank below clear candidates of the same class
    — another job is already making them, so a different pick widens
    aggregate coverage at no extra cost.  A separate jitted variant:
    the mask-free twins keep their historical compiled programs (and
    draw sequences) untouched."""
    return _substitute_core(state, requested, rng, n_jobs, None, inflight)


substitute_inflight_jit = jax.jit(substitute_inflight,
                                  static_argnames=("n_jobs",))


def substitute_tiered_inflight(state: ODSJaxState, requested: jax.Array,
                               rng: jax.Array, n_jobs: int,
                               residency: jax.Array, inflight: jax.Array
                               ) -> Tuple[ODSJaxState, jax.Array,
                                          jax.Array]:
    """Residency- and coalescing-aware ODS batch step: tier order
    first (:func:`substitute_tiered`), clear-before-in-flight within
    each tier."""
    return _substitute_core(state, requested, rng, n_jobs, residency,
                            inflight)


substitute_tiered_inflight_jit = jax.jit(substitute_tiered_inflight,
                                         static_argnames=("n_jobs",))
