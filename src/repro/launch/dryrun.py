"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the fake-device flag before ANY other import (jax locks the device
count on first init).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse                                              # noqa: E402
import json                                                  # noqa: E402
import time                                                  # noqa: E402
import traceback                                             # noqa: E402
from typing import Any, Dict, Optional, Tuple                # noqa: E402

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs import registry                           # noqa: E402
from repro.configs.base import (SHAPES_BY_NAME, ALL_SHAPES,  # noqa: E402
                                ParallelismConfig, ShapeConfig,
                                shape_applicable)
from repro.distributed.compat import set_mesh                # noqa: E402
from repro.distributed.sharding import make_rules, use_rules  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models.model import Model, build                  # noqa: E402
from repro.models.params import (abstract_params,            # noqa: E402
                                 param_bytes, partition_specs)
from repro.roofline import analysis as roofline              # noqa: E402
from repro.roofline import hlo_collectives                   # noqa: E402
from repro.train.optimizer import AdamW, Quantized           # noqa: E402
from repro.train.step import build_train_step                # noqa: E402

SDS = jax.ShapeDtypeStruct


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _opt_specs(params_specs, m_abs, fsdp: bool, dp: int):
    def f(st, spec):
        if isinstance(st, Quantized):
            parts = list(spec) + [None] * (st.q.ndim - 1 - len(spec))
            if st.q.ndim == len(parts) + 1:
                # structured blocks (..., D/Q, Q): inherit the param spec;
                # a sharded trailing param axis moves to the blocks axis
                # when the block count still divides the mesh axis
                last = parts[-1] if parts else None
                keep_last = last if (last is not None and
                                     st.q.shape[-2] % 16 == 0) else None
                qspec = P(*parts[:-1], keep_last, None)
                sspec = qspec
            else:                      # flat fallback (small params)
                nb = st.q.shape[0]
                qspec = P("data", None) if (fsdp and nb % dp == 0) else P()
                sspec = qspec
            return Quantized(qspec, sspec)
        return spec

    return jax.tree.map(f, m_abs, params_specs,
                        is_leaf=lambda x: isinstance(x, Quantized))


def _shard_factor(spec: P, mesh) -> int:
    f = 1
    for ax in spec:
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        for a in axes:
            f *= mesh.shape[a]
    return f


def _bytes_per_device(abs_tree, spec_tree, mesh) -> float:
    total = 0.0
    leaves_a = jax.tree.leaves(abs_tree)
    leaves_s = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    for a, s in zip(leaves_a, leaves_s):
        nb = np.prod(a.shape) * jnp.dtype(a.dtype).itemsize
        total += nb / _shard_factor(s, mesh)
    return float(total)


def lower_cell(arch: str, shape: ShapeConfig, *, multi_pod: bool,
               parallel: Optional[ParallelismConfig] = None) -> Dict:
    """Lower+compile one cell; returns the record dict (or raises)."""
    cfg = registry.get(arch)
    model = build(cfg)
    parallel = parallel or registry.default_parallelism(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = make_rules(cfg, shape, parallel, multi_pod=multi_pod,
                       tp_size=mesh.shape["model"],
                       dp_size=mesh.shape["data"], mesh=mesh)

    defs = model.param_defs()
    p_abs = abstract_params(defs, jnp.dtype(parallel.param_dtype))
    p_specs = partition_specs(defs, rules.mapping)
    in_specs_batch = {
        k: rules.spec(*axes)
        for k, axes in model.batch_logical_axes(shape).items()}
    batch_abs = model.input_specs(shape)

    t0 = time.monotonic()
    with use_rules(rules), set_mesh(mesh):
        if shape.is_train:
            opt = AdamW(state_dtype=parallel.opt_state_dtype)
            o_abs = jax.eval_shape(opt.init, p_abs)
            m_specs = _opt_specs(p_specs, o_abs.m, parallel.fsdp,
                                 mesh.shape["data"])
            o_specs = type(o_abs)(step=P(), m=m_specs, v=m_specs)
            step = build_train_step(model, parallel, opt)
            jitted = jax.jit(
                step,
                in_shardings=(_ns(mesh, p_specs), _ns(mesh, o_specs),
                              _ns(mesh, in_specs_batch)),
                out_shardings=(_ns(mesh, p_specs), _ns(mesh, o_specs),
                               None))
            lowered = jitted.lower(p_abs, o_abs, batch_abs)
            extra_bytes = _bytes_per_device(o_abs, o_specs, mesh)
            kind_note = "train_step"
        elif shape.kind == "prefill":
            c_defs = model.cache_defs(shape.global_batch, shape.seq_len)
            c_abs = abstract_params(c_defs) if cfg.has_decoder and \
                cfg.family not in ("ssm", "hybrid") else \
                abstract_params(c_defs)
            c_specs = partition_specs(c_defs, rules.mapping)

            def prefill_fn(params, batch, cache):
                return model.prefill(params, batch, cache,
                                     remat=parallel.remat)

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(_ns(mesh, p_specs), _ns(mesh, in_specs_batch),
                              _ns(mesh, c_specs)),
                out_shardings=(None, _ns(mesh, c_specs)))
            lowered = jitted.lower(p_abs, batch_abs, c_abs)
            extra_bytes = _bytes_per_device(c_abs, c_specs, mesh)
            kind_note = "prefill_step"
        else:  # decode
            c_defs = model.cache_defs(shape.global_batch, shape.seq_len)
            c_abs = abstract_params(c_defs)
            c_specs = partition_specs(c_defs, rules.mapping)
            tok_abs = SDS((shape.global_batch, 1), jnp.int32)

            def decode_fn(params, cache, tokens, index):
                return model.decode_step(params, cache, tokens, index)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(_ns(mesh, p_specs), _ns(mesh, c_specs),
                              _ns(mesh, rules.spec("batch", None)),
                              NamedSharding(mesh, P())),
                out_shardings=(None, _ns(mesh, c_specs)))
            lowered = jitted.lower(p_abs, c_abs, tok_abs,
                                   SDS((), jnp.int32))
            extra_bytes = _bytes_per_device(c_abs, c_specs, mesh)
            kind_note = "serve_step"

        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_stats = {
                k: getattr(mem, k) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "peak_memory_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:           # CPU backend may not support it
            mem_stats = {"error": str(e)}
        hlo = compiled.as_text()
        coll = hlo_collectives.analyze(hlo)

    rec = roofline.build_record(
        arch=arch, shape=shape, cfg=cfg,
        mesh_name="2x16x16" if multi_pod else "16x16", chips=chips,
        cost=cost, wire_bytes=coll.total_wire_bytes,
        collectives=dict(coll.per_kind_bytes), note=kind_note)

    params_bpd = _bytes_per_device(p_abs, p_specs, mesh)
    return {
        **{k: v for k, v in rec.__dict__.items()},
        "memory_analysis": {k: float(v) if not isinstance(v, str) else v
                            for k, v in mem_stats.items()},
        "analytic_bytes_per_device": {
            "params": params_bpd, "state_or_cache": extra_bytes,
            "total": params_bpd + extra_bytes},
        "collective_counts": dict(coll.per_kind_count),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "parallelism": parallel.__dict__,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="comma list or 'all' (assigned archs)")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="ParallelismConfig override key=value (perf "
                         "hillclimbing), e.g. --set microbatches=8")
    args = ap.parse_args()

    overrides = {}
    for kv in args.overrides:
        k, v = kv.split("=", 1)
        cur = getattr(ParallelismConfig(), k)
        overrides[k] = type(cur)(int(v) if isinstance(cur, (bool, int))
                                 and v.isdigit() else v) \
            if not isinstance(cur, bool) else v in ("1", "true", "True")

    archs = list(registry.ASSIGNED_ARCHS) if args.arch == "all" \
        else args.arch.split(",")
    shapes = [s.name for s in ALL_SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = args.mesh.split(",")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict[str, Any] = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        cfg = registry.get(arch)
        for sname in shapes:
            shape = SHAPES_BY_NAME[sname]
            ok, why = shape_applicable(cfg, shape)
            for mesh_kind in meshes:
                key = f"{arch}|{sname}|{mesh_kind}"
                if key in results and "error" not in results[key] \
                        and not args.force:
                    print(f"[skip cached] {key}")
                    continue
                if not ok:
                    results[key] = {"skipped": why}
                    print(f"[skip n/a] {key}: {why}")
                    continue
                print(f"[lower+compile] {key} ...", flush=True)
                t0 = time.monotonic()
                try:
                    par = None
                    if overrides:
                        par = registry.default_parallelism(
                            cfg, shape).replace(**overrides)
                    rec = lower_cell(arch, shape,
                                     multi_pod=(mesh_kind == "multi"),
                                     parallel=par)
                    results[key] = rec
                    print(f"  ok in {time.monotonic()-t0:.0f}s "
                          f"bottleneck={rec['bottleneck']} "
                          f"frac={rec['roofline_fraction']:.2f}",
                          flush=True)
                except Exception as e:
                    results[key] = {"error": str(e),
                                    "traceback": traceback.format_exc()}
                    print(f"  FAILED: {e}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)
    n_ok = sum(1 for v in results.values()
               if "error" not in v and "skipped" not in v)
    n_err = sum(1 for v in results.values() if "error" in v)
    print(f"done: {n_ok} ok, {n_err} failed, "
          f"{len(results) - n_ok - n_err} skipped -> {args.out}")


if __name__ == "__main__":
    main()
