"""End-to-end training driver.

Wires the Seneca data service (MDP + ODS), the threaded DSI pipeline, the
model zoo, the optimizer, and fault tolerance into one runnable loop:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --reduced --steps 200 --batch 32 --seq 128

``--reduced`` swaps in the smoke-scale config so the driver runs on CPU;
the full configs are exercised through the dry-run.  For the image-model
path (--arch vit-huge) batches come from the real Seneca image pipeline;
LM archs use the token pipeline (synthetic corpus through the same cache).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import AZURE_NC96, GB, SenecaServer
from repro.configs import registry
from repro.configs.base import ShapeConfig, ParallelismConfig
from repro.data.pipeline import DSIPipeline
from repro.data.storage import RemoteStorage
from repro.data.synthetic import tiny
from repro.distributed.ft import FTConfig, ResilientTrainer
from repro.models.model import build, make_batch
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.step import build_train_step


def lm_batch_source(model, batch: int, seq: int, seed: int = 0):
    """Synthetic-corpus LM batches (deterministic token stream)."""
    rng = np.random.default_rng(seed)
    V = model.cfg.vocab_size

    def next_batch():
        toks = rng.integers(0, V, size=(batch, seq + 1), dtype=np.int64)
        b = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if model.cfg.family == "vlm":
            p = model.cfg.frontend_tokens
            b["tokens"] = b["tokens"][:, :seq - p]
            b["patch_embeds"] = jnp.asarray(
                rng.normal(size=(batch, p, model.cfg.d_model)),
                jnp.bfloat16)
            b["labels"] = jnp.asarray(toks[:, 1:seq + 1], jnp.int32)
        if model.cfg.family in ("encdec", "audio"):
            from repro.models.transformer import encdec_src_len
            b["src_embeds"] = jnp.asarray(
                rng.normal(size=(batch, encdec_src_len(seq),
                                 model.cfg.d_model)), jnp.bfloat16)
        return b

    return next_batch


def image_batch_source(model, batch: int, seed: int = 0,
                       backend: str = "numpy"):
    """Real Seneca pipeline: storage -> 3-form cache -> ODS -> augment.

    Returns (next_batch, pipeline, server); the server is the
    :class:`repro.api.SenecaServer` facade — open more sessions on it for
    concurrent jobs."""
    ds = tiny(n=4096)
    storage = RemoteStorage(ds, bandwidth=None)
    server = SenecaServer.for_dataset(ds, cache_bytes=int(0.2 * GB),
                                      hardware=AZURE_NC96, seed=seed,
                                      backend=backend)
    pipe = DSIPipeline(server.open_session(batch_size=batch), storage,
                       n_workers=4)
    d = model.cfg.d_model

    def next_batch():
        raw = pipe.next_batch()
        imgs = raw["images"]
        B, H, W, _ = imgs.shape
        T = model.cfg.frontend_tokens
        # stub patchify: average-pool grid -> (B, T, D) embeddings
        flat = imgs.reshape(B, -1)
        reps = int(np.ceil(T * d / flat.shape[1]))
        emb = np.tile(flat, (1, reps))[:, :T * d].reshape(B, T, d)
        return {"patch_embeds": jnp.asarray(emb, jnp.bfloat16),
                "labels": jnp.asarray(raw["labels"] %
                                      max(model.cfg.n_classes, 1),
                                      jnp.int32)}

    return next_batch, pipe, server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b",
                    choices=registry.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch) if args.reduced \
        else registry.get(args.arch)
    model = build(cfg)
    print(f"arch={cfg.name} params={model.n_params():,} "
          f"(reduced={args.reduced})")

    params = model.init(jax.random.key(0))
    opt = AdamW(lr=args.lr,
                schedule=warmup_cosine(args.lr, 20, args.steps))
    opt_state = opt.init(params)
    parallel = ParallelismConfig(microbatches=args.microbatches)
    step = jax.jit(build_train_step(model, parallel, opt))

    pipe = None
    if cfg.family == "encoder":
        source, pipe, server = image_batch_source(model, args.batch)
        print(f"seneca partition: {server.partition.label}")
    else:
        source = lm_batch_source(model, args.batch, args.seq)

    trainer = ResilientTrainer(
        step_fn=step, params=params, opt_state=opt_state,
        cfg=FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        batch_source=source)
    t0 = time.monotonic()
    hist = trainer.run(args.steps)
    dt = time.monotonic() - t0
    print(f"{len(hist)} steps in {dt:.1f}s "
          f"({len(hist) * args.batch / dt:.1f} samples/s)")
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    if pipe is not None:
        print("pipeline stage seconds:", pipe.times.as_dict())
        print("seneca stats:", server.stats())
        pipe.stop()


if __name__ == "__main__":
    main()
