"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the 'pod' axis is the
DCN dimension.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)}; the dry-run launcher must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"any jax import")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto, devices=devices[:n])


def make_debug_mesh(n_devices: int = 0, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    model = 1
    for m in (4, 2, 1):
        if n % m == 0 and n >= m:
            model = m
            break
    mesh_devs = np.asarray(devs[:n]).reshape(n // model, model)
    return jax.sharding.Mesh(mesh_devs, axes)
