"""Serving driver: batched decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --requests 8 --max-new 16

``--open-loop RATE`` feeds the resident model from the open-loop
preprocessing generator instead of a pre-built request list: requests
arrive on a Poisson schedule at RATE req/s, each is preprocessed through
a live Seneca cache (with SLO admission control), and every completed
sample becomes a prompt for the decode loop.  Prints the preprocessing
latency percentiles (p50/p99/p999 + per-phase breakdown) alongside the
decode throughput.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models.model import build
from repro.serve.step import Request, Server


def _open_loop_requests(args, vocab_size: int):
    """Run the open-loop preprocessing stage and map every completed
    sample to a decode Request (prompt tokens derived from the
    preprocessed pixels, so the prompt depends on the served form)."""
    from repro.api import SLO, SenecaServer
    from repro.data import synthetic
    from repro.data.storage import RemoteStorage
    from repro.workload import OpenLoopGenerator, poisson_arrivals

    ds = synthetic.tiny(n=256)
    seneca = SenecaServer.for_dataset(ds, cache_frac=0.3)
    storage = RemoteStorage(ds, bandwidth=8e6)
    lock = threading.Lock()
    pending = []

    def consumer(res, value) -> None:
        arr = np.asarray(value, np.float32).ravel()
        tok = (np.abs(arr[:args.prompt_len]) * 1e4).astype(np.int64) \
            % vocab_size
        with lock:
            pending.append(Request(res.req_id, tok.astype(np.int32),
                                   max_new=args.max_new,
                                   arrival_s=res.arrival_s))

    gen = OpenLoopGenerator(
        seneca, storage, consumer=consumer,
        slo=SLO(p99_target_s=args.slo_p99, max_queue=64),
        n_workers=2, seed=0)
    result = gen.run(poisson_arrivals(args.open_loop, n=args.requests,
                                      seed=0))
    seneca.close()
    print(f"open-loop preprocessing @ {args.open_loop:.0f} req/s: "
          f"{result.counts}")
    lat = result.percentiles()
    if lat:
        print(f"  latency p50={lat['p50'] * 1e3:.2f}ms "
              f"p99={lat['p99'] * 1e3:.2f}ms "
              f"p999={lat['p999'] * 1e3:.2f}ms")
        for phase, pcts in sorted(result.phase_percentiles().items()):
            print(f"  {phase:>8}: p50={pcts['p50'] * 1e3:.2f}ms "
                  f"p99={pcts['p99'] * 1e3:.2f}ms")
    return pending


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b",
                    choices=registry.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--open-loop", type=float, default=None, metavar="RATE",
                    help="feed requests from the open-loop preprocessing "
                         "generator at RATE req/s (Poisson arrivals, SLO "
                         "admission control) instead of a pre-built list")
    ap.add_argument("--slo-p99", type=float, default=0.2,
                    help="open-loop p99 latency target in seconds")
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch) if args.reduced \
        else registry.get(args.arch)
    if not cfg.has_decoder:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode path")
    model = build(cfg)
    params = model.init(jax.random.key(0))
    server = Server(model, params, n_slots=args.slots, s_max=args.s_max)

    if args.open_loop is not None:
        pending = _open_loop_requests(args, cfg.vocab_size)
        if not pending:
            raise SystemExit("open-loop stage shed every request; lower "
                             "the rate or raise --slo-p99")
    else:
        rng = np.random.default_rng(0)
        pending = [Request(i, rng.integers(0, cfg.vocab_size,
                                           size=args.prompt_len))
                   for i in range(args.requests)]
    n_requests = len(pending)
    done = []
    t0 = time.monotonic()
    while pending or any(s is not None for s in server.slots):
        while pending and server.add_request(pending[0]):
            req = pending.pop(0)
            print(f"  admitted request {req.req_id}")
        if not server.decode_round():
            break
        for i, s in enumerate(server.slots):
            if s is not None and s.done:
                done.append(s)
                server.slots[i] = None
    dt = time.monotonic() - t0
    total_tok = sum(len(r.generated)
                    for r in done) + n_requests * args.prompt_len
    print(f"{n_requests} requests, {total_tok} tokens in {dt:.1f}s "
          f"({total_tok / dt:.1f} tok/s, {server.steps} decode steps)")


if __name__ == "__main__":
    main()
