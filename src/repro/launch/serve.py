"""Serving driver: batched decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models.model import build
from repro.serve.step import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b",
                    choices=registry.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=128)
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch) if args.reduced \
        else registry.get(args.arch)
    if not cfg.has_decoder:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode path")
    model = build(cfg)
    params = model.init(jax.random.key(0))
    server = Server(model, params, n_slots=args.slots, s_max=args.s_max)

    rng = np.random.default_rng(0)
    pending = [Request(i, rng.integers(0, cfg.vocab_size,
                                       size=args.prompt_len))
               for i in range(args.requests)]
    done = []
    t0 = time.monotonic()
    while pending or any(s is not None for s in server.slots):
        while pending and server.add_request(pending[0]):
            req = pending.pop(0)
            print(f"  admitted request {req.req_id}")
        if not server.decode_round():
            break
        for i, s in enumerate(server.slots):
            if s is not None and s.done:
                done.append(s)
                server.slots[i] = None
    dt = time.monotonic() - t0
    total_tok = sum(len(r.generated)
                    for r in done) + args.requests * args.prompt_len
    print(f"{args.requests} requests, {total_tok} tokens in {dt:.1f}s "
          f"({total_tok / dt:.1f} tok/s, {server.steps} decode steps)")


if __name__ == "__main__":
    main()
