"""Deterministic synthetic multimedia dataset — in-memory and on-disk.

Samples are generated from a per-id PRNG so any worker on any host can
materialize sample ``i`` without shared state — the property real object
stores give you and the one checkpoint/restart relies on.

Encoded sizes follow a lognormal around the dataset's mean (Table 6 stats),
clipped to [0.25x, 4x] of the mean, mimicking JPEG size spread.

:class:`FileDataset` materializes the same samples into write-once
sharded files so the live pipeline exercises *real* file IO (open /
mmap / copy) instead of PRNG calls; byte-identical payloads, same
interface, drop-in behind :class:`~repro.data.storage.RemoteStorage`.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

# splitmix32-style counter hash: the canonical "JPEG decode" pixel PRNG.
# Every pixel byte is a pure function of (base seed, flat pixel index) in
# exact uint32 wraparound math, so the jnp/Pallas decode kernel
# (repro.kernels.decode) reproduces it bit-for-bit on device — something a
# stateful NumPy Generator could never offer.  Changing any constant here
# breaks the kernel parity tests.
_HASH_STEP = 0x9E3779B9          # golden-ratio counter increment
_HASH_M1 = 0x7FEB352D
_HASH_M2 = 0x846CA68B


def pixel_hash(base: int, n: int) -> np.ndarray:
    """uint8[n] pixel stream for counter indices 0..n-1 (host reference).

    ``base`` is the per-sample seed, reduced mod 2**32; all arithmetic
    wraps in uint32 exactly like the device twin
    :func:`repro.kernels.decode.ref.pixel_hash_jnp`.
    """
    idx = np.arange(n, dtype=np.uint32)
    x = np.uint32(base & 0xFFFFFFFF) + idx * np.uint32(_HASH_STEP)
    x ^= x >> np.uint32(16)
    x *= np.uint32(_HASH_M1)
    x ^= x >> np.uint32(15)
    x *= np.uint32(_HASH_M2)
    x ^= x >> np.uint32(16)
    return (x & np.uint32(0xFF)).astype(np.uint8)


@dataclass(frozen=True)
class SyntheticDataset:
    name: str
    n_samples: int
    mean_encoded_bytes: int
    image_hw: Tuple[int, int] = (256, 256)
    crop_hw: Tuple[int, int] = (224, 224)
    n_classes: int = 1000
    seed: int = 1234

    def encoded_size(self, sample_id: int) -> int:
        rng = np.random.default_rng(self.seed + sample_id)
        s = rng.lognormal(mean=0.0, sigma=0.35)
        s = float(np.clip(s, 0.25, 4.0))
        return max(int(self.mean_encoded_bytes * s), 1024)

    def encoded(self, sample_id: int) -> bytes:
        """The 'file on storage' for this sample (header + payload)."""
        n = self.encoded_size(sample_id)
        rng = np.random.default_rng(self.seed + sample_id)
        # realistic cost: materialize the payload (I/O-sized buffer)
        payload = rng.integers(0, 256, size=n, dtype=np.uint8)
        return payload.tobytes()

    def label(self, sample_id: int) -> int:
        return (sample_id * 2654435761) % self.n_classes

    def decode_base_seed(self, sample_id: int) -> int:
        """The per-sample counter-hash base seed (mod 2**32) — the host
        half of the device decode contract (repro.kernels.decode)."""
        return (self.seed * 31 + sample_id) & 0xFFFFFFFF

    @staticmethod
    def decode_head_mix(encoded: bytes) -> int:
        """Payload statistic folded into every pixel (0..255): the sum of
        the first 4 KiB, so decode actually reads the buffer."""
        head = np.frombuffer(encoded[:4096], dtype=np.uint8)
        return int(head.sum()) % 256

    def decode(self, encoded: bytes, sample_id: int) -> np.ndarray:
        """'JPEG decode': deterministic uint8 HWC image derived from the
        payload.  Does real CPU work proportional to the image area.

        Pixels come from the counter hash (:func:`pixel_hash`) over the
        per-sample base seed, plus a payload-header mix — exactly the
        semantics the fused Pallas decode kernel reproduces on device.
        """
        h, w = self.image_hw
        img = pixel_hash(self.decode_base_seed(sample_id),
                         h * w * 3).reshape(h, w, 3)
        img = (img.astype(np.int32) + self.decode_head_mix(encoded)) % 256
        return img.astype(np.uint8)

    def decoded_bytes(self) -> int:
        h, w = self.image_hw
        return h * w * 3

    def augmented_bytes(self, dtype_size: int = 4) -> int:
        h, w = self.crop_hw
        return h * w * 3 * dtype_size

    def inflation(self, dtype_size: int = 4) -> float:
        return self.augmented_bytes(dtype_size) / self.mean_encoded_bytes


@dataclass(frozen=True)
class DecodeHeavyDataset(SyntheticDataset):
    """A :class:`SyntheticDataset` whose decode burns extra CPU inside
    the GIL — a pure-Python byte fold over the encoded payload.

    Decode time scales with ``decode_work`` irrespective of image size,
    so the sharded-data-plane benchmark can dial CPU-bound decode cost
    without inflating cache footprints.  Still frozen and picklable, so
    it ships to spawned shard processes unchanged.
    """

    decode_work: int = 16_384    # payload bytes folded per decode

    def decode(self, encoded: bytes, sample_id: int) -> np.ndarray:
        acc = 0
        for b in encoded[:self.decode_work]:   # deliberate: holds the GIL
            acc = (acc * 31 + b) & 0xFFFFFFFF
        img = super().decode(encoded, sample_id)
        # fold the checksum in so the work cannot be dead-code-eliminated
        # and stays deterministic per (payload, id)
        return ((img.astype(np.int32) + acc % 7) % 256).astype(np.uint8)


class FileDataset:
    """Sharded on-disk materialization of a :class:`SyntheticDataset`.

    ``root`` gains write-once shard files (``shard-00000.bin`` …, each
    up to ``shard_bytes`` of concatenated encoded payloads) plus an
    ``index.npz`` mapping sample id -> (shard, offset, length).  A
    second construction over the same root reuses the files (the index
    is validated against the dataset's name/size), so benchmarks and
    the workload runner pay materialization once per machine.

    Reads go through one ``np.memmap`` per shard — ``encoded(i)``
    copies the sample's byte range out of the mapping, which is a real
    page-cache/disk read, unlike the PRNG-backed base dataset.  All
    other behavior (decode, labels, per-form sizes) delegates to the
    base dataset; payloads are byte-identical by construction, so the
    two are interchangeable mid-experiment.
    """

    def __init__(self, base: SyntheticDataset, root: str,
                 shard_bytes: int = 16 << 20):
        self.base = base
        self.root = root
        self.shard_bytes = int(shard_bytes)
        self._mmaps: Dict[int, np.memmap] = {}
        os.makedirs(root, exist_ok=True)
        self._index_path = os.path.join(root, "index.npz")
        if os.path.exists(self._index_path):
            idx = np.load(self._index_path, allow_pickle=False)
            if (str(idx["name"]) != base.name
                    or int(idx["n_samples"]) != base.n_samples
                    or int(idx["seed"]) != base.seed):
                raise ValueError(
                    f"{root} holds shards for dataset "
                    f"{idx['name']}/{idx['n_samples']}, not "
                    f"{base.name}/{base.n_samples}; use a fresh root")
            self.shard_of = idx["shard"]
            self.offset_of = idx["offset"]
            self.length_of = idx["length"]
            self.n_shards = int(self.shard_of[-1]) + 1 \
                if len(self.shard_of) else 0
        else:
            self._materialize()

    def _materialize(self) -> None:
        n = self.base.n_samples
        shard_of = np.zeros(n, np.int32)
        offset_of = np.zeros(n, np.int64)
        length_of = np.zeros(n, np.int64)
        shard, offset, f = 0, 0, None
        try:
            for i in range(n):
                payload = self.base.encoded(i)
                if f is None or (offset and
                                 offset + len(payload) > self.shard_bytes):
                    if f is not None:
                        f.close()
                    shard = shard + 1 if f is not None else 0
                    offset = 0
                    f = open(self._shard_path(shard), "wb")
                shard_of[i], offset_of[i] = shard, offset
                length_of[i] = len(payload)
                f.write(payload)
                offset += len(payload)
        finally:
            if f is not None:
                f.close()
        self.shard_of, self.offset_of = shard_of, offset_of
        self.length_of = length_of
        self.n_shards = shard + 1 if n else 0
        np.savez(self._index_path, shard=shard_of, offset=offset_of,
                 length=length_of, name=self.base.name,
                 n_samples=self.base.n_samples, seed=self.base.seed)

    def _shard_path(self, shard: int) -> str:
        return os.path.join(self.root, f"shard-{shard:05d}.bin")

    def _mmap(self, shard: int) -> np.memmap:
        mm = self._mmaps.get(shard)
        if mm is None:
            mm = np.memmap(self._shard_path(shard), dtype=np.uint8,
                           mode="r")
            self._mmaps[shard] = mm
        return mm

    # -- the SyntheticDataset interface --------------------------------
    @property
    def name(self) -> str:
        return f"{self.base.name}@file"

    @property
    def n_samples(self) -> int:
        return self.base.n_samples

    @property
    def mean_encoded_bytes(self) -> int:
        return self.base.mean_encoded_bytes

    @property
    def image_hw(self) -> Tuple[int, int]:
        return self.base.image_hw

    @property
    def crop_hw(self) -> Tuple[int, int]:
        return self.base.crop_hw

    @property
    def n_classes(self) -> int:
        return self.base.n_classes

    @property
    def seed(self) -> int:
        return self.base.seed

    def encoded_size(self, sample_id: int) -> int:
        return int(self.length_of[sample_id])

    def encoded(self, sample_id: int) -> bytes:
        mm = self._mmap(int(self.shard_of[sample_id]))
        off = int(self.offset_of[sample_id])
        return bytes(mm[off:off + int(self.length_of[sample_id])])

    def label(self, sample_id: int) -> int:
        return self.base.label(sample_id)

    def decode(self, encoded: bytes, sample_id: int) -> np.ndarray:
        return self.base.decode(encoded, sample_id)

    def decoded_bytes(self) -> int:
        return self.base.decoded_bytes()

    def augmented_bytes(self, dtype_size: int = 4) -> int:
        return self.base.augmented_bytes(dtype_size)

    def inflation(self, dtype_size: int = 4) -> float:
        return self.base.inflation(dtype_size)

    def total_bytes(self) -> int:
        return int(self.length_of.sum())

    def close(self) -> None:
        """Drop the shard mappings (the files stay — they are the
        dataset).  ``remove_files()`` deletes those too."""
        self._mmaps.clear()

    def remove_files(self) -> None:
        self.close()
        for shard in range(self.n_shards):
            try:
                os.unlink(self._shard_path(shard))
            except OSError:
                pass
        try:
            os.unlink(self._index_path)
            os.rmdir(self.root)
        except OSError:
            pass


# paper-shaped datasets scaled down for CPU-runnable examples/tests
def tiny(n: int = 2048, mean_bytes: int = 24_000) -> SyntheticDataset:
    return SyntheticDataset("tiny", n, mean_bytes, image_hw=(64, 64),
                            crop_hw=(56, 56), n_classes=100)


def imagenet_like(n: int = 1_300_000) -> SyntheticDataset:
    return SyntheticDataset("imagenet-1k-like", n, 114_620)


def openimages_like(n: int = 1_900_000) -> SyntheticDataset:
    return SyntheticDataset("openimages-like", n, 315_840)
