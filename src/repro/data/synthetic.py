"""Deterministic synthetic multimedia dataset.

Samples are generated from a per-id PRNG so any worker on any host can
materialize sample ``i`` without shared state — the property real object
stores give you and the one checkpoint/restart relies on.

Encoded sizes follow a lognormal around the dataset's mean (Table 6 stats),
clipped to [0.25x, 4x] of the mean, mimicking JPEG size spread.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class SyntheticDataset:
    name: str
    n_samples: int
    mean_encoded_bytes: int
    image_hw: Tuple[int, int] = (256, 256)
    crop_hw: Tuple[int, int] = (224, 224)
    n_classes: int = 1000
    seed: int = 1234

    def encoded_size(self, sample_id: int) -> int:
        rng = np.random.default_rng(self.seed + sample_id)
        s = rng.lognormal(mean=0.0, sigma=0.35)
        s = float(np.clip(s, 0.25, 4.0))
        return max(int(self.mean_encoded_bytes * s), 1024)

    def encoded(self, sample_id: int) -> bytes:
        """The 'file on storage' for this sample (header + payload)."""
        n = self.encoded_size(sample_id)
        rng = np.random.default_rng(self.seed + sample_id)
        # realistic cost: materialize the payload (I/O-sized buffer)
        payload = rng.integers(0, 256, size=n, dtype=np.uint8)
        return payload.tobytes()

    def label(self, sample_id: int) -> int:
        return (sample_id * 2654435761) % self.n_classes

    def decode(self, encoded: bytes, sample_id: int) -> np.ndarray:
        """'JPEG decode': deterministic uint8 HWC image derived from the
        payload.  Does real CPU work proportional to the image area."""
        h, w = self.image_hw
        rng = np.random.default_rng(self.seed * 31 + sample_id)
        img = rng.integers(0, 256, size=(h, w, 3), dtype=np.uint8)
        # mix in payload statistics so decode actually reads the buffer
        head = np.frombuffer(encoded[:4096], dtype=np.uint8)
        img = (img.astype(np.int32) + int(head.sum()) % 256) % 256
        return img.astype(np.uint8)

    def decoded_bytes(self) -> int:
        h, w = self.image_hw
        return h * w * 3

    def augmented_bytes(self, dtype_size: int = 4) -> int:
        h, w = self.crop_hw
        return h * w * 3 * dtype_size

    def inflation(self, dtype_size: int = 4) -> float:
        return self.augmented_bytes(dtype_size) / self.mean_encoded_bytes


# paper-shaped datasets scaled down for CPU-runnable examples/tests
def tiny(n: int = 2048, mean_bytes: int = 24_000) -> SyntheticDataset:
    return SyntheticDataset("tiny", n, mean_bytes, image_hw=(64, 64),
                            crop_hw=(56, 56), n_classes=100)


def imagenet_like(n: int = 1_300_000) -> SyntheticDataset:
    return SyntheticDataset("imagenet-1k-like", n, 114_620)


def openimages_like(n: int = 1_900_000) -> SyntheticDataset:
    return SyntheticDataset("openimages-like", n, 315_840)
