"""The real (threaded) DSI pipeline: sampler -> fetch -> decode -> augment
-> collate -> device.

Feeds from a :class:`repro.api.Session` over the shared Seneca service
(MDP-partitioned cache + pluggable sampling/admission/eviction policies),
so the paper's concurrency experiments run for real on CPU::

    server = SenecaServer.for_dataset(ds)
    pipe = DSIPipeline(server.open_session(batch_size=32), storage)
    batch = pipe.next_batch()

Three executors (the ``executor=`` knob):

* ``"per-sample"`` (default, the seed behavior): every sample runs
  fetch->decode->augment serially inside one worker, ``next_batch`` is a
  synchronous barrier over the whole batch.
* ``"device"``: device-resident preprocessing — encoded samples go
  through the fused Pallas decode+augment kernel
  (:func:`repro.kernels.augment.ops.decode_augment_batch_seeded`) in one
  launch per batch (only per-sample scalars cross the PCIe link), HBM
  cache hits serve zero-copy device arrays, and the collated
  ``"images"`` tensor is a ``jax.Array`` ready for the training step.
  Host→device payload copies (DRAM/disk hits, decoded-hit uploads) are
  metered on the telemetry ``"h2d"`` channel, which calibrates
  ``HardwareProfile.b_hbm`` — an all-HBM-hit epoch records zero bytes
  there.  Synchronous and single-threaded like ``"per-sample"``
  (VirtualClock-deterministic with ``sync_refills``); requires a
  dataset whose ``decode`` is the counter-hash
  ``SyntheticDataset.decode`` (see :func:`fused_decode_seed`).
* ``"stage-parallel"``: a decoupled asynchronous executor — bounded
  queues between sampler -> fetch -> decode -> augment -> collate,
  per-stage worker groups sized from the service telemetry's stage EWMAs
  (:func:`plan_stage_workers`), an augment stage that batches decoded
  samples through the service's vectorized
  :class:`~repro.api.backends.AugmentBackend` (Pallas kernel or NumPy
  loop), and batch-granular cache admission (one lock acquisition per
  admitted batch via ``Session.admit_batch``).  Batches are emitted in
  sampling order; batch N+1's storage fetches overlap batch N's
  decode/augment, so throughput approaches the slowest *stage* instead
  of the per-batch sum (benchmarks/fig_pipeline_throughput.py).

Both executors produce identical tensors for a given (epoch, sample id):
augmentation parameters derive from per-sample seeds, not executor
scheduling.  Batches carry an additive ``"ids"`` key with the sample ids
in slot order.

Cache admission goes through the service's :class:`AdmissionPolicy` hooks
(capacity is voted under the cache lock, atomically with the insert) —
this module never touches cache partitions directly.

The old ``DSIPipeline(job_id, service, storage, batch_size=...)`` call
style still works as a deprecated shim that opens a session internally.
"""
from __future__ import annotations

import logging
import queue
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.api.server import SenecaService, Session, SessionClosed
from repro.data.augment import augment_np
from repro.data.storage import RemoteStorage
from repro.data.synthetic import SyntheticDataset

log = logging.getLogger(__name__)

EXECUTORS = ("per-sample", "stage-parallel", "device")


def _aug_seed(epoch_tag: int, sid: int) -> int:
    """The per-sample augmentation seed — shared by every executor and
    both augment backends, so batch composition never changes content."""
    return (epoch_tag * 1_000_003 + sid) & 0x7FFFFFFF


def fused_decode_seed(ds) -> Optional[int]:
    """The dataset's decode-PRNG seed when its ``decode`` is the
    counter-hash ``SyntheticDataset.decode`` the fused Pallas kernel
    reimplements; ``None`` for any dataset that overrides ``decode``
    (e.g. ``DecodeHeavyDataset``) — the device executor refuses those at
    construction rather than silently diverging from the host path.
    Thin lazy wrapper over :func:`repro.kernels.decode.ops` so importing
    this module never pulls in jax."""
    from repro.kernels.decode.ops import fused_decode_seed as impl
    return impl(ds)


@dataclass
class StageTimes:
    fetch: float = 0.0
    decode: float = 0.0
    augment: float = 0.0
    collate: float = 0.0
    batches: int = 0

    def as_dict(self) -> Dict[str, float]:
        return {"fetch": self.fetch, "decode": self.decode,
                "augment": self.augment, "collate": self.collate,
                "batches": self.batches}


def plan_stage_workers(telemetry, n_workers: int) -> Tuple[int, int]:
    """Size the (fetch, decode) worker groups from the telemetry stage
    EWMAs.

    The ``n_workers`` budget is split proportionally to the observed
    storage-fetch vs decode latencies (clamped to >= 1 each; an even
    split until both signals exist, with a budget floor of 2).  The
    fetch share is then doubled: fetch workers spend most of their time
    parked in storage waits (token bucket / network), so 2x
    oversubscription keeps the storage channel busy through the GIL
    pauses of the CPU stages — decode keeps the plain CPU share.  The
    stage-parallel executor re-plans this every batch as the EWMAs move
    (elastic groups), so a pipeline that starts cache-cold and becomes
    decode-bound sheds fetch workers live.
    """
    total = max(int(n_workers), 2)
    lat = telemetry.snapshot().stage_latency
    fetch, decode = lat.get("fetch_storage"), lat.get("decode")
    if not fetch or not decode:
        base_fetch = max(total // 2, 1)
    else:
        base_fetch = int(round(total * fetch / (fetch + decode)))
        base_fetch = min(max(base_fetch, 1), total - 1)
    return 2 * base_fetch, total - base_fetch


class _Assembly:
    """One in-flight batch: slots fill in as samples finish their route.

    ``arrived`` is touched only by the single augment-stage thread (every
    sample's route ends there, pre-augmented cache hits included), which
    is what makes batch completion race-free without a per-batch lock.
    """

    __slots__ = ("seq", "ids", "epoch", "out", "arrived")

    def __init__(self, seq: int, ids: List[int], epoch: int):
        self.seq = seq
        self.ids = ids
        self.epoch = epoch
        self.out: List[Optional[np.ndarray]] = [None] * len(ids)
        self.arrived = 0


class _StageParallelExecutor:
    """Queue-fed stage pipeline over one DSIPipeline's session/storage.

    Thread layout: 1 sampler, ``n_fetch`` fetch workers, ``n_decode``
    decode workers, 1 augment (vectorized, batch-granular admission),
    1 collate (in-order emission, refill + repartition ticks).  Bounded
    queues propagate consumer backpressure all the way to the sampler;
    every put/get is stop-aware so teardown never deadlocks.
    """

    def __init__(self, pipe: "DSIPipeline", out_depth: int):
        self.pipe = pipe
        bs = pipe.bs
        self._stop = threading.Event()
        self.error: Optional[BaseException] = None
        self._session_closed = False
        self.fetch_q: "queue.Queue" = queue.Queue(maxsize=2 * bs)
        self.decode_q: "queue.Queue" = queue.Queue(maxsize=2 * bs)
        self.augment_q: "queue.Queue" = queue.Queue(maxsize=2 * bs)
        self.collate_q: "queue.Queue" = queue.Queue(maxsize=out_depth + 1)
        self.out_q: "queue.Queue" = queue.Queue(maxsize=max(out_depth, 1))
        # elastic worker groups: live/target counts per resizable stage.
        # The collate thread re-plans targets from telemetry every batch;
        # surplus workers retire themselves, missing ones are spawned.
        self._group_lock = threading.Lock()
        self._live = {"fetch": 0, "decode": 0}
        self._target = dict(zip(("fetch", "decode"), plan_stage_workers(
            pipe.telemetry, pipe._n_workers)))
        self._last_plan = dict(self._target)
        self._group_loops = {"fetch": self._fetch_loop,
                             "decode": self._decode_loop}
        self._threads: List[threading.Thread] = []
        for target, name in ((self._sampler_loop, "sampler"),
                             (self._augment_loop, "augment"),
                             (self._collate_loop, "collate")):
            t = threading.Thread(target=target, daemon=True,
                                 name=f"dsi-{name}")
            self._threads.append(t)
            t.start()
        self._reconcile_groups()

    # -- elastic worker groups -----------------------------------------
    def worker_counts(self) -> Dict[str, int]:
        with self._group_lock:
            return dict(self._live)

    def _resize_groups(self) -> None:
        """Re-plan the fetch/decode group sizes from the current stage
        EWMAs (collate thread, once per batch), debounced: a new plan is
        applied only when two consecutive batches agree on it, so EWMA
        jitter flapping across a rounding boundary cannot churn worker
        threads every batch, while any persistent shift in the stage
        balance lands within two batches."""
        planned = dict(zip(("fetch", "decode"), plan_stage_workers(
            self.pipe.telemetry, self.pipe._n_workers)))
        with self._group_lock:
            if planned == self._last_plan:
                self._target.update(planned)
            self._last_plan = planned
        self._reconcile_groups()

    def _reconcile_groups(self) -> None:
        """Spawn workers up to the group targets (retiring is the worker
        loops' own job) and drop finished threads from the join list so
        it cannot grow without bound across retarget cycles."""
        spawn: List[str] = []
        with self._group_lock:
            for group, tgt in self._target.items():
                while self._live[group] < tgt:
                    self._live[group] += 1
                    spawn.append(group)
        self._threads = [t for t in self._threads if t.is_alive()]
        for group in spawn:
            t = threading.Thread(target=self._group_loops[group],
                                 daemon=True, name=f"dsi-{group}")
            self._threads.append(t)
            t.start()

    def _surplus(self, group: str) -> bool:
        """True when this worker should retire (its group shrank)."""
        with self._group_lock:
            if self._live[group] > self._target[group]:
                self._live[group] -= 1
                return True
        return False

    # -- stop-aware queue plumbing -------------------------------------
    def _put(self, q: "queue.Queue", item) -> bool:
        while not self._stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _get(self, q: "queue.Queue"):
        while not self._stop.is_set():
            try:
                return q.get(timeout=0.1)
            except queue.Empty:
                continue
        return None

    def _fail(self, exc: BaseException) -> None:
        """First failure wins: record, surface in telemetry, halt the
        executor (an incomplete assembly can never collate, so limping
        on would just hang the consumer)."""
        if self.error is None:
            self.error = exc
        if self.pipe.telemetry.record_error("pipeline") == 1:
            log.warning("stage-parallel executor failed; first error:",
                        exc_info=exc)
        self._stop.set()

    # -- stages --------------------------------------------------------
    def _sampler_loop(self) -> None:
        seq = 0
        pipe = self.pipe
        while not self._stop.is_set():
            try:
                ids, _forms = pipe.session.next_batch_ids()
            except SessionClosed:
                # normal lifecycle, not a failure — but the consumer must
                # fail fast like the per-sample executor does, not block
                # out a full get_batch timeout on a drained queue
                self._session_closed = True
                self._stop.set()
                return
            except Exception as e:      # noqa: BLE001 - recorded, not lost
                self._fail(e)
                return
            asm = _Assembly(seq, [int(x) for x in ids], pipe.session.epoch)
            seq += 1
            for slot in range(len(asm.ids)):
                if not self._put(self.fetch_q, (asm, slot)):
                    return

    def _fetch_loop(self) -> None:
        pipe = self.pipe
        tel = pipe.telemetry
        while not self._stop.is_set():
            if self._surplus("fetch"):
                return
            item = self._get(self.fetch_q)
            if item is None:
                return
            asm, slot = item
            sid = asm.ids[slot]
            try:
                t_look = pipe._now()
                form, value, tier = pipe.session.lookup_tiered(sid)
                tel.record_serve(form)
                t0 = pipe._now()
                if form is None:
                    ok = self._fetch_miss(asm, slot, sid)
                else:
                    pipe.times.fetch += t0 - t_look
                    tel.record_stage("fetch_cache", t0 - t_look)
                    nbytes = value.nbytes if hasattr(value, "nbytes") \
                        else len(value)
                    # spill-tier hits calibrate b_disk, DRAM hits b_cache
                    tel.record_bytes("disk" if tier == "disk" else "cache",
                                     nbytes, t0 - t_look)
                    if form == "augmented":
                        ok = self._put(self.augment_q,
                                       (asm, slot, value, None, False, True,
                                        None))
                    elif form == "decoded":
                        ok = self._put(self.augment_q,
                                       (asm, slot, value, None, False,
                                        False, None))
                    else:                        # encoded cache hit
                        ok = self._put(self.decode_q,
                                       (asm, slot, value, False, None))
                if not ok:
                    return
            except Exception as e:      # noqa: BLE001
                self._fail(e)
                return

    def _fetch_miss(self, asm: "_Assembly", slot: int, sid: int) -> bool:
        """Storage-miss path of the fetch stage, single-flight aware:
        the leader fetches and carries its flight through decode ->
        augment (finished with the augmented row in `_augment_group`);
        joiners receive the finished value and skip straight to the
        pre-augmented queue."""
        pipe = self.pipe
        tel = pipe.telemetry
        prod = pipe._production
        flight = None
        while prod is not None:
            leader, flight = prod.begin(sid, "augmented")
            if leader:
                break            # flight is None in observe mode
            t_j = pipe._now()
            ok, joined = prod.join(flight, pipe._clock)
            if ok:
                tel.record_coalesced(max(pipe._now() - t_j, 0.0))
                return self._put(self.augment_q,
                                 (asm, slot, joined, None, False, True,
                                  None))
            if not flight.done:
                # wait declined or timed out: produce ourselves
                flight = None
                break
            # leader aborted: retry begin(); the first retrier leads
        t0 = pipe._now()
        try:
            enc = pipe.storage.fetch(sid)
        except BaseException:
            if prod is not None:
                prod.abort(flight)
            raise
        dt = pipe._now() - t0
        pipe.times.fetch += dt
        tel.record_stage("fetch_storage", dt)
        tel.record_bytes("storage", len(enc), dt)
        ok = self._put(self.decode_q, (asm, slot, enc, True, flight))
        if not ok and prod is not None:
            prod.abort(flight)   # shutting down: don't strand joiners
        return ok

    def _decode_loop(self) -> None:
        pipe = self.pipe
        while not self._stop.is_set():
            if self._surplus("decode"):
                return
            item = self._get(self.decode_q)
            if item is None:
                return
            asm, slot, enc, from_storage, flight = item
            try:
                t1 = pipe._now()
                img = pipe.ds.decode(enc, asm.ids[slot])
                dt = pipe._now() - t1
                pipe.times.decode += dt
                # unlocked _live read: an approximate worker count is
                # fine for the calibration scale factor
                pipe.telemetry.record_stage(
                    "decode", dt, workers=max(self._live["decode"], 1))
                # carry enc along only when it still needs admission, so
                # the augment stage can batch-admit the encoded form too
                if not self._put(self.augment_q,
                                 (asm, slot, img,
                                  enc if from_storage else None, True,
                                  False, flight)):
                    if pipe._production is not None:
                        pipe._production.abort(flight)
                    return
            except Exception as e:      # noqa: BLE001
                if pipe._production is not None:
                    pipe._production.abort(flight)
                self._fail(e)
                return

    def _augment_loop(self) -> None:
        pipe = self.pipe
        sess = pipe.session
        # per-assembly buffers of samples awaiting vectorized augmentation:
        # seq -> [(slot, img, enc_to_admit, admit_decoded, flight)]
        buffers: Dict[int, List] = {}
        while not self._stop.is_set():
            item = self._get(self.augment_q)
            if item is None:
                return
            asm, slot, payload, enc, admit_dec, pre, flight = item
            try:
                if pre:
                    asm.out[slot] = payload
                else:
                    buffers.setdefault(asm.seq, []).append(
                        (slot, payload, enc, admit_dec, flight))
                asm.arrived += 1
                if asm.arrived < len(asm.ids):
                    continue
                group = buffers.pop(asm.seq, [])
                if group:
                    self._augment_group(sess, asm, group)
                if not self._put(self.collate_q, asm):
                    return
            except Exception as e:      # noqa: BLE001
                self._fail(e)
                return

    def _augment_group(self, sess: Session, asm: _Assembly,
                       group: List) -> None:
        """Vectorized augment + batch-granular admission for the samples
        of one assembly that were not served pre-augmented."""
        pipe = self.pipe
        try:
            self._augment_group_inner(sess, asm, group)
        except BaseException:
            prod = pipe._production
            if prod is not None:
                # no flight was finished yet (the hand-off loop is the
                # inner body's last step): wake every joiner to retry
                for _slot, _img, _enc, _ad, fl in group:
                    prod.abort(fl)
            raise

    def _augment_group_inner(self, sess: Session, asm: _Assembly,
                             group: List) -> None:
        pipe = self.pipe
        enc_entries = [(asm.ids[slot], enc, len(enc))
                       for slot, _img, enc, _ad, _fl in group
                       if enc is not None]
        if enc_entries:
            sess.admit_batch("encoded", enc_entries)
        dec_entries = [(asm.ids[slot], img, img.nbytes)
                       for slot, img, _enc, ad, _fl in group if ad]
        if dec_entries:
            sess.admit_batch("decoded", dec_entries)
        slots = [slot for slot, _img, _enc, _ad, _fl in group]
        imgs = np.stack([img for _slot, img, _enc, _ad, _fl in group])
        seeds = np.asarray([_aug_seed(asm.epoch, asm.ids[s]) for s in slots],
                           np.int64)
        t2 = pipe._now()
        outs = pipe.augment.augment_batch(imgs, pipe.ds.crop_hw, seeds)
        dt = pipe._now() - t2
        pipe.times.augment += dt
        # the augment stage is one thread, not the whole worker pool:
        # report that, or calibrate() would overestimate t_a ~n_workers x
        pipe.telemetry.record_stage("augment", dt, n=len(slots), workers=1)
        # np.array copies: cached rows must not pin the whole batch
        # array.  Pre-vote the metadata half of admission so the copies
        # are only built for entries the policy would take — under
        # unseen-only admission a single-session pipeline's own samples
        # are all already seen, so this skips B row copies per batch
        if pipe.svc.tier_capacity("augmented") > 0:
            ids = [asm.ids[s] for s in slots]
            wanted = pipe.svc.admission_votes("augmented", ids)
            entries = [(sid, np.array(outs[i]), outs[i].nbytes)
                       for i, (sid, w) in enumerate(zip(ids, wanted)) if w]
            if entries:
                sess.admit_batch("augmented", entries)
        for i, s in enumerate(slots):
            asm.out[s] = outs[i]
        prod = pipe._production
        if prod is not None:
            for i, (_slot, _img, _enc, _ad, fl) in enumerate(group):
                if fl is not None:
                    # np.array copy: the handed-off row must not pin
                    # the whole batch array in every joiner's cache
                    prod.finish(fl, np.array(outs[i]))

    def _collate_loop(self) -> None:
        pipe = self.pipe
        pending: Dict[int, _Assembly] = {}
        next_seq = 0
        while not self._stop.is_set():
            asm = self._get(self.collate_q)
            if asm is None:
                return
            try:
                pending[asm.seq] = asm
                while next_seq in pending:     # emit in sampling order
                    asm = pending.pop(next_seq)
                    t0 = pipe._now()
                    batch = {
                        # copy=False: backends return float32 already —
                        # don't re-copy the whole batch on the one
                        # thread that serializes emission
                        "images": np.stack(asm.out).astype(np.float32,
                                                           copy=False),
                        "labels": np.asarray(
                            [pipe.ds.label(s) for s in asm.ids], np.int32),
                        "ids": np.asarray(asm.ids, np.int64),
                    }
                    dt = pipe._now() - t0
                    pipe.times.collate += dt
                    pipe.telemetry.record_stage("collate", dt,
                                                n=len(asm.ids))
                    pipe.times.batches += 1
                    pipe._process_refills()
                    pipe.svc.maybe_repartition()
                    self._gauge_queues()
                    self._resize_groups()
                    if not self._put(self.out_q, batch):
                        return
                    next_seq += 1
            except Exception as e:      # noqa: BLE001 - same contract as
                self._fail(e)           # every other stage loop: no
                return                  # silent thread death

    def _gauge_queues(self) -> None:
        tel = self.pipe.telemetry
        for name, q in (("fetch", self.fetch_q), ("decode", self.decode_q),
                        ("augment", self.augment_q),
                        ("collate", self.collate_q), ("out", self.out_q)):
            tel.record_queue(name, q.qsize(), q.maxsize)

    # -- consumer side -------------------------------------------------
    def get_batch(self,
                  timeout: Optional[float] = 60.0
                  ) -> Dict[str, np.ndarray]:
        """Next collated batch.  ``timeout=None`` blocks until one is
        ready (``next_batch`` semantics — a slow pipeline is not an
        error); a finite timeout raises ``queue.Empty`` at the deadline
        (``get`` semantics, matching the per-sample prefetch queue).

        The inner poll is capped at the *remaining* deadline, never a
        fixed quantum: a finite ``timeout < 0.2`` used to overshoot by
        up to a full 0.2 s poll interval before the deadline was even
        checked."""
        deadline = float("inf") if timeout is None \
            else time.monotonic() + timeout
        while True:
            wait = min(0.2, deadline - time.monotonic()) \
                if deadline != float("inf") else 0.2
            try:
                return self.out_q.get(timeout=max(wait, 0.0))
            except queue.Empty:
                if self.error is not None:
                    raise RuntimeError(
                        "stage-parallel pipeline failed; see telemetry "
                        "errors") from self.error
                if self._session_closed:
                    raise SessionClosed(
                        "session closed while the stage-parallel "
                        "pipeline was running; open a new one with "
                        "SenecaServer.open_session()")
                if self._stop.is_set():
                    raise RuntimeError(
                        "stage-parallel pipeline is stopped")
                if time.monotonic() >= deadline:
                    raise

    def stop(self) -> None:
        self._stop.set()
        for t in list(self._threads):
            t.join(timeout=2.0)
        # don't leave this executor's group sizes scaling latencies that
        # a per-sample pipeline on the same service reports afterwards
        self.pipe.telemetry.clear_stage_workers("decode", "augment")


class DSIPipeline:
    """Per-session pipeline over a shared Seneca service + RemoteStorage."""

    def __init__(self, session, storage: Optional[RemoteStorage] = None,
                 *legacy_storage, batch_size: Optional[int] = None,
                 n_workers: int = 4, prefetch: int = 2, seed: int = 0,
                 executor: str = "per-sample", augment_backend=None,
                 consume_hook=None, sync_refills: bool = False,
                 clock=None):
        # validate before any side effect: the legacy path below
        # registers a job on the shared service, which must not leak
        # when construction fails
        if executor not in EXECUTORS:
            raise ValueError(f"unknown executor {executor!r}; expected "
                             f"one of {EXECUTORS}")
        if isinstance(session, Session):
            self.session = session
            if not isinstance(storage, RemoteStorage):
                raise TypeError("DSIPipeline(session, storage) needs a "
                                "RemoteStorage as its second argument")
        else:
            # legacy (job_id, service, storage, batch_size=...) call style
            warnings.warn(
                "DSIPipeline(job_id, service, storage, batch_size=...) is "
                "deprecated; pass a Session from "
                "SenecaServer.open_session()", DeprecationWarning,
                stacklevel=2)
            job_id, service = int(session), storage
            if len(legacy_storage) > 1 and batch_size is None:
                batch_size = legacy_storage[1]   # old positional form
            if not (isinstance(service, SenecaService) and legacy_storage
                    and batch_size):
                raise TypeError(
                    "expected DSIPipeline(session, storage) or legacy "
                    "DSIPipeline(job_id, service, storage, batch_size=N)")
            storage = legacy_storage[0]
            service.register_job(job_id, batch_size)
            self.session = Session(service, job_id, batch_size)
        self.executor = executor
        self.svc: SenecaService = self.session.service
        self.storage = storage
        self.ds: SyntheticDataset = storage.dataset
        self._fused_seed: Optional[int] = None
        if executor == "device":
            self._fused_seed = fused_decode_seed(self.ds)
            if self._fused_seed is None:
                raise ValueError(
                    "device executor needs a dataset whose decode is the "
                    "counter-hash SyntheticDataset.decode (the fused "
                    f"kernel's semantics); got {type(self.ds).__name__}")
        self.bs = self.session.batch_size
        self.pool = ThreadPoolExecutor(max_workers=n_workers)
        self.times = StageTimes()
        # pluggable time source for per-request/stage phase timestamps
        # (duck-typed Clock: .now()).  None keeps the historical wall
        # clock; a VirtualClock makes every recorded phase a *trace*
        # time — storage stalls charged through the clock-aware token
        # bucket then show up in fetch telemetry deterministically,
        # while pure-compute phases cost zero virtual seconds.
        # Host-side liveness deadlines (queue polls, thread joins) stay
        # on wall time regardless.
        self._now = time.monotonic if clock is None else clock.now
        self._clock = clock
        # cross-job single-flight table (service-level; None for bare
        # service doubles in tests) — consulted before producing a miss
        self._production = getattr(self.svc, "production", None)
        # telemetry feeds the adaptive repartition loop: per-stage EWMAs,
        # transfer bandwidths, per-form serve counts and (stage-parallel)
        # queue gauges, aggregated across every pipeline on the service
        self.telemetry = self.svc.telemetry
        self._n_workers = n_workers
        self.telemetry.add_concurrency(n_workers)
        self.rng = np.random.default_rng(seed + self.session.job_id)
        # batched augmentation engine (stage-parallel augment stage):
        # service-level knob, overridable per pipeline
        if augment_backend is None:
            self.augment = self.svc.augment
        else:
            from repro.api.backends import resolve_augment_backend
            self.augment = resolve_augment_backend(augment_backend)
        # consumer-rate hook: called with every batch ``next_batch``
        # emits, on the emitting thread, before the batch is returned.
        # The WorkloadRunner installs a rate limiter here to emulate GPU
        # ingest (repro/workload/runner.py); anything callable works.
        self._consume_hook = consume_hook
        # deterministic mode: run background refills inline on the
        # calling thread instead of racing them on the worker pool
        # (required for byte-identical virtual-clock workload runs)
        self._sync_refills = sync_refills
        self._prefetch_depth = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._prefetch_exc: Optional[BaseException] = None
        self._executor: Optional[_StageParallelExecutor] = None
        self._executor_lock = threading.Lock()

    # ------------------------------------------------------------------
    def _produce_sample(self, sid: int, epoch_tag: int) -> np.ndarray:
        """Run one sample through the remaining pipeline stages."""
        t_look = self._now()
        form, value, tier = self.session.lookup_tiered(sid)
        self.telemetry.record_serve(form)
        # spill-tier hits calibrate b_disk, DRAM hits b_cache
        channel = "disk" if tier == "disk" else "cache"
        t0 = self._now()
        if form == "augmented":
            # hit cost is the lookup interval (t0 - t_look): StageTimes
            # and telemetry account the same thing (the seed charged
            # "now - t0" ~ 0 here, undercounting every hit)
            self.times.fetch += t0 - t_look
            self.telemetry.record_stage("fetch_cache", t0 - t_look)
            self.telemetry.record_bytes(channel, value.nbytes, t0 - t_look)
            return value
        if form is not None:
            # decoded/encoded hit: the lookup interval is charged here,
            # the remaining production stages in _produce_miss
            nbytes = value.nbytes if form == "decoded" else len(value)
            self.times.fetch += t0 - t_look
            self.telemetry.record_stage("fetch_cache", t0 - t_look)
            self.telemetry.record_bytes(channel, nbytes, t0 - t_look)
        prod = self._production
        if prod is None:
            return self._produce_miss(sid, epoch_tag, form, value)
        # single-flight: first misser of (sid, "augmented") leads and
        # produces; concurrent missers join and receive the result
        # zero-copy, or fall back to producing when waiting is unsafe
        while True:
            leader, flight = prod.begin(sid, "augmented")
            if leader:
                if flight is None:   # observe mode: duplicate, but live
                    return self._produce_miss(sid, epoch_tag, form, value)
                try:
                    out = self._produce_miss(sid, epoch_tag, form, value)
                except BaseException as e:
                    prod.abort(flight, e)
                    raise
                prod.finish(flight, out)
                return out
            t_j = self._now()
            ok, joined = prod.join(flight, self._clock)
            if ok:
                self.telemetry.record_coalesced(max(self._now() - t_j, 0.0))
                return joined
            if not flight.done:
                # wait declined (deterministic clock, no bound ticket)
                # or timed out on a wedged leader: produce ourselves —
                # a duplicate production, never a stall
                return self._produce_miss(sid, epoch_tag, form, value)
            # leader aborted: retry begin(); the first retrier leads

    def _produce_miss(self, sid: int, epoch_tag: int,
                      form: Optional[str], value) -> np.ndarray:
        """Remaining stages for a sample not cached in augmented form:
        fetch/decode as ``form`` requires, then augment + admit."""
        if form == "decoded":
            img = value
        elif form == "encoded":
            t1 = self._now()
            img = self.ds.decode(value, sid)
            dt = self._now() - t1
            self.times.decode += dt
            self.telemetry.record_stage("decode", dt)
            self.session.admit(sid, "decoded", img, img.nbytes)
        else:
            t0 = self._now()
            enc = self.storage.fetch(sid)
            dt = self._now() - t0
            self.times.fetch += dt
            self.telemetry.record_stage("fetch_storage", dt)
            self.telemetry.record_bytes("storage", len(enc), dt)
            self.session.admit(sid, "encoded", enc, len(enc))
            t1 = self._now()
            img = self.ds.decode(enc, sid)
            dt = self._now() - t1
            self.times.decode += dt
            self.telemetry.record_stage("decode", dt)
            self.session.admit(sid, "decoded", img, img.nbytes)
        t2 = self._now()
        out = augment_np(img, self.ds.crop_hw,
                         np.random.default_rng(_aug_seed(epoch_tag, sid)))
        dt = self._now() - t2
        self.times.augment += dt
        self.telemetry.record_stage("augment", dt)
        self.session.admit(sid, "augmented", out, out.nbytes)
        return out

    # ------------------------------------------------------------------
    def next_batch(self) -> Dict[str, np.ndarray]:
        if self.executor == "stage-parallel":
            # block until produced, like the per-sample path: slowness is
            # backpressure, not failure (errors still raise immediately)
            batch = self._ensure_executor().get_batch(timeout=None)
            if self._consume_hook is not None:
                self._consume_hook(batch)
            return batch
        if self.executor == "device":
            batch = self._next_batch_device()
            if self._consume_hook is not None:
                self._consume_hook(batch)
            return batch
        ids, _forms = self.session.next_batch_ids()
        epoch_tag = self.session.epoch
        imgs = list(self.pool.map(
            lambda s: self._produce_sample(int(s), epoch_tag), ids))
        t0 = self._now()
        batch = {
            "images": np.stack(imgs).astype(np.float32),
            "labels": np.asarray([self.ds.label(int(s)) for s in ids],
                                 np.int32),
            "ids": np.asarray(ids, np.int64),
        }
        dt = self._now() - t0
        self.times.collate += dt
        self.telemetry.record_stage("collate", dt, n=len(ids))
        self.times.batches += 1
        self._process_refills()
        # adaptive-repartition tick: a fast no-op in "static"/"on-change"
        # modes; in "adaptive" this is where calibrated drift is checked
        self.svc.maybe_repartition()
        if self._consume_hook is not None:
            self._consume_hook(batch)
        return batch

    def _next_batch_device(self) -> Dict[str, np.ndarray]:
        """One batch through the device route: fused decode+augment for
        encoded samples, zero-copy serve for HBM hits, device collate.

        Every sample ends as a device row; the only host→device payload
        traffic (metered on the ``"h2d"`` channel) is DRAM/disk-cached
        values being uploaded.  Encoded samples never materialize a host
        decoded image — the fused kernel ships per-sample scalars only —
        so (by design) this route admits no "decoded" forms.

        Telemetry timings block on JAX async dispatch
        (``block_until_ready``) before the closing timestamp — otherwise
        the h2d EWMA feeding the CALIBRATABLE ``b_hbm`` and the fused
        stage times would measure dispatch latency, not the transfer or
        compute, and mis-steer MDP repartitioning.
        """
        import jax
        import jax.numpy as jnp

        from repro.kernels.augment.ops import (augment_batch_seeded,
                                               decode_augment_batch_seeded)
        tel = self.telemetry
        ids, _forms = self.session.next_batch_ids()
        epoch_tag = self.session.epoch
        rows: List = [None] * len(ids)
        enc_group: List[Tuple[int, int, bytes]] = []   # (slot, sid, payload)
        dec_group: List[Tuple[int, int, np.ndarray]] = []
        dec_dev_group: List[Tuple[int, int, object]] = []  # HBM decoded hits
        for slot, sid_ in enumerate(ids):
            sid = int(sid_)
            t_look = self._now()
            form, value, tier = self.session.lookup_tiered(sid)
            tel.record_serve(form)
            t0 = self._now()
            if form is None:
                enc = self.storage.fetch(sid)
                dt = self._now() - t0
                self.times.fetch += dt
                tel.record_stage("fetch_storage", dt)
                tel.record_bytes("storage", len(enc), dt)
                self.session.admit(sid, "encoded", enc, len(enc))
                enc_group.append((slot, sid, enc))
                continue
            self.times.fetch += t0 - t_look
            tel.record_stage("fetch_cache", t0 - t_look)
            if form == "augmented" and tier == "hbm":
                # zero-copy device serve: no h2d traffic at all
                rows[slot] = value
                continue
            channel = "disk" if tier == "disk" else "cache"
            if form == "augmented":
                host = np.asarray(value)
                tel.record_bytes(channel, host.nbytes, t0 - t_look)
                t1 = self._now()
                rows[slot] = jax.block_until_ready(jnp.asarray(host))
                tel.record_bytes("h2d", host.nbytes,
                                 self._now() - t1)
            elif form == "decoded":
                if tier == "hbm":
                    # device-resident decoded hit: augment on device —
                    # no host round-trip, so no byte-channel record (a
                    # d2h download metered as "cache" would skew b_cache)
                    dec_dev_group.append((slot, sid, value))
                else:
                    img = np.asarray(value)
                    tel.record_bytes(channel, img.nbytes, t0 - t_look)
                    dec_group.append((slot, sid, img))
            else:                                      # encoded cache hit
                tel.record_bytes(channel, len(value), t0 - t_look)
                enc_group.append((slot, sid, value))
        fresh: List[Tuple[int, object]] = []           # (sid, device row)
        if enc_group:
            sids = [sid for _s, sid, _p in enc_group]
            seeds = np.asarray([_aug_seed(epoch_tag, sid) for sid in sids],
                               np.int64)
            t1 = self._now()
            out = jax.block_until_ready(decode_augment_batch_seeded(
                [p for _s, _sid, p in enc_group], sids, seeds,
                ds_seed=self._fused_seed, image_hw=self.ds.image_hw,
                crop_h=self.ds.crop_hw[0], crop_w=self.ds.crop_hw[1]))
            dt = self._now() - t1
            # one fused launch covers both stages; split its time evenly
            # so the calibrated t_da = conc/(decode+augment) lands on
            # the fused rate
            self.times.decode += dt / 2
            self.times.augment += dt / 2
            tel.record_stage("decode", dt / 2, n=len(enc_group))
            tel.record_stage("augment", dt / 2, n=len(enc_group))
            for i, (slot, sid, _p) in enumerate(enc_group):
                rows[slot] = out[i]
                fresh.append((sid, out[i]))
        if dec_group:
            sids = [sid for _s, sid, _img in dec_group]
            imgs = np.stack([img for _s, _sid, img in dec_group])
            seeds = np.asarray([_aug_seed(epoch_tag, sid) for sid in sids],
                               np.int64)
            t1 = self._now()
            out = jax.block_until_ready(
                augment_batch_seeded(imgs, seeds, *self.ds.crop_hw,
                                     as_device=True))
            dt = self._now() - t1
            self.times.augment += dt
            tel.record_stage("augment", dt, n=len(dec_group))
            # decoded pixels shipped up for the device-side augment
            tel.record_bytes("h2d", imgs.nbytes, dt)
            for i, (slot, sid, _img) in enumerate(dec_group):
                rows[slot] = out[i]
                fresh.append((sid, out[i]))
        if dec_dev_group:
            sids = [sid for _s, sid, _img in dec_dev_group]
            imgs_dev = jnp.stack([img for _s, _sid, img in dec_dev_group])
            seeds = np.asarray([_aug_seed(epoch_tag, sid) for sid in sids],
                               np.int64)
            t1 = self._now()
            out = jax.block_until_ready(
                augment_batch_seeded(imgs_dev, seeds, *self.ds.crop_hw,
                                     as_device=True))
            dt = self._now() - t1
            self.times.augment += dt
            tel.record_stage("augment", dt, n=len(dec_dev_group))
            # pixels were already device-resident: no h2d traffic
            for i, (slot, sid, _img) in enumerate(dec_dev_group):
                rows[slot] = out[i]
                fresh.append((sid, out[i]))
        # admit the freshly augmented device rows: HBM-first put routing
        # keeps them device-resident; without a device tier admit host
        # copies so a DRAM slot never pins a jax buffer
        if fresh and self.svc.tier_capacity("augmented") > 0:
            wanted = self.svc.admission_votes("augmented",
                                              [sid for sid, _r in fresh])
            entries = [(sid, row if self.svc.has_hbm else np.asarray(row),
                        int(row.nbytes))
                       for (sid, row), w in zip(fresh, wanted) if w]
            if entries:
                self.session.admit_batch("augmented", entries)
        t0 = self._now()
        batch = {
            "images": jnp.stack(rows).astype(jnp.float32),
            "labels": np.asarray([self.ds.label(int(s)) for s in ids],
                                 np.int32),
            "ids": np.asarray(ids, np.int64),
        }
        dt = self._now() - t0
        self.times.collate += dt
        tel.record_stage("collate", dt, n=len(ids))
        self.times.batches += 1
        self._process_refills()
        self.svc.maybe_repartition()
        return batch

    def _process_refills(self, max_n: int = 32) -> None:
        """ODS step 5: repopulate evicted augmented slots with *fresh*
        random samples (unseen by every job), on the worker pool — the
        paper's background-refill thread.  Also proactively tops up free
        augmented capacity (cold start)."""
        work = self.svc.take_refill_work(max_n)
        spare = max_n - len(work)
        if spare > 0 and self.svc.tier_capacity("augmented"):
            free_slots = self.svc.tier_free_bytes("augmented") \
                // max(self.ds.augmented_bytes(), 1)
            if free_slots > 0:
                extra = self.svc.refill_candidates(min(spare, free_slots))
                work = np.concatenate([work, extra]) if len(work) else extra
        for sid in work:
            if self._sync_refills:
                self._refill_one(int(sid))
            else:
                self.pool.submit(self._refill_one, int(sid))

    def _refill_one(self, sid: int) -> None:
        flight = None
        prod = self._production
        try:
            # a raced refill/admit may already have repopulated this
            # slot; form_of() is stats-neutral and containment-only, so
            # the check neither inflates misses nor reads a spilled
            # payload off disk just to learn the form
            if self.svc.cache.form_of(sid) == "augmented":
                return
            if prod is not None:
                leader, fl = prod.begin(sid, "augmented")
                if not leader:
                    # a foreground production of this id is already in
                    # flight and will admit the augmented form itself —
                    # the refill would be pure duplicate work
                    return
                flight = fl
            enc = self.storage.fetch(sid)
            img = self.ds.decode(enc, sid)
            out = augment_np(img, self.ds.crop_hw,
                             np.random.default_rng(sid ^ 0x5EED))
            self.session.admit(sid, "augmented", out, out.nbytes)
            if prod is not None:
                prod.finish(flight, out)
                flight = None
        except Exception:      # background worker must never kill serving
            if prod is not None and flight is not None:
                prod.abort(flight)   # wake joiners; the first retries
            # ... but it must not fail silently either: count every
            # failure (stats()["refill_errors"]) and log the first
            if self.telemetry.record_error("refill") == 1:
                log.warning(
                    "background refill failed for sample %d (first "
                    "occurrence; later failures only counted in "
                    "stats()['refill_errors'])", sid, exc_info=True)

    # ------------------------------------------------------------------
    def _ensure_executor(self) -> _StageParallelExecutor:
        with self._executor_lock:
            if self._executor is None:
                self._executor = _StageParallelExecutor(
                    self, out_depth=max(self._prefetch_depth, 1))
            return self._executor

    def start_prefetch(self) -> None:
        if self.executor == "stage-parallel":
            # the stage executor IS the prefetcher: out_q holds up to
            # ``prefetch`` collated batches
            self._ensure_executor()
            return

        def run():
            batch = None
            while not self._stop.is_set():
                if batch is None:
                    try:
                        batch = self.next_batch()
                    except Exception as e:   # noqa: BLE001
                        # record (don't silently die): get() re-raises
                        self._prefetch_exc = e
                        if self.telemetry.record_error("prefetch") == 1:
                            log.warning("prefetch thread failed in "
                                        "next_batch()", exc_info=True)
                        return
                try:
                    self._q.put(batch, timeout=0.5)
                except queue.Full:
                    # consumer is slow: hold the built batch and re-offer
                    # it (the seed rebuilt a fresh batch here, silently
                    # dropping this one's sample ids and wasting the work)
                    continue
                batch = None
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def get(self, timeout: float = 60.0) -> Dict[str, np.ndarray]:
        if self.executor == "stage-parallel":
            batch = self._ensure_executor().get_batch(timeout)
            # same contract as next_batch(): the hook fires once per
            # emitted batch.  (On the per-sample path below, batches
            # reach the queue via the prefetch thread's next_batch(),
            # which already fired it.)
            if self._consume_hook is not None:
                self._consume_hook(batch)
            return batch
        deadline = time.monotonic() + timeout
        while True:
            # cap the poll at the remaining deadline (sub-poll timeouts
            # must not overshoot by a whole 0.2 s quantum)
            wait = min(0.2, deadline - time.monotonic())
            try:
                return self._q.get(timeout=max(wait, 0.0))
            except queue.Empty:
                if self._prefetch_exc is not None:
                    raise RuntimeError(
                        "prefetch thread died; no more batches are "
                        "coming") from self._prefetch_exc
                if time.monotonic() >= deadline:
                    raise

    def stop(self, close_session: bool = True) -> None:
        """Tear the pipeline down.  ``close_session=False`` keeps the
        session (and its sampler state) alive — the fault-recovery path
        rebuilds a fresh pipeline on the surviving session after a
        worker crash or around a preemption."""
        if not self._stop.is_set():
            self.telemetry.remove_concurrency(self._n_workers)
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)
        if self._executor is not None:
            self._executor.stop()
        self.pool.shutdown(wait=False)
        if close_session:
            self.session.close()
