"""The real (threaded) DSI pipeline: sampler -> fetch -> decode -> augment
-> collate -> device.

Feeds from a :class:`repro.api.Session` over the shared Seneca service
(MDP-partitioned cache + pluggable sampling/admission/eviction policies),
so the paper's concurrency experiments run for real on CPU::

    server = SenecaServer.for_dataset(ds)
    pipe = DSIPipeline(server.open_session(batch_size=32), storage)
    batch = pipe.next_batch()

Cache admission goes through the service's :class:`AdmissionPolicy` hooks
(capacity is voted under the cache lock, atomically with the insert) —
this module never touches cache partitions directly.

The old ``DSIPipeline(job_id, service, storage, batch_size=...)`` call
style still works as a deprecated shim that opens a session internally.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.api.server import SenecaService, Session
from repro.data.augment import augment_np
from repro.data.storage import RemoteStorage
from repro.data.synthetic import SyntheticDataset


@dataclass
class StageTimes:
    fetch: float = 0.0
    decode: float = 0.0
    augment: float = 0.0
    collate: float = 0.0
    batches: int = 0

    def as_dict(self) -> Dict[str, float]:
        return {"fetch": self.fetch, "decode": self.decode,
                "augment": self.augment, "collate": self.collate,
                "batches": self.batches}


class DSIPipeline:
    """Per-session pipeline over a shared Seneca service + RemoteStorage."""

    def __init__(self, session, storage: Optional[RemoteStorage] = None,
                 *legacy_storage, batch_size: Optional[int] = None,
                 n_workers: int = 4, prefetch: int = 2, seed: int = 0):
        if isinstance(session, Session):
            self.session = session
            if not isinstance(storage, RemoteStorage):
                raise TypeError("DSIPipeline(session, storage) needs a "
                                "RemoteStorage as its second argument")
        else:
            # legacy (job_id, service, storage, batch_size=...) call style
            warnings.warn(
                "DSIPipeline(job_id, service, storage, batch_size=...) is "
                "deprecated; pass a Session from "
                "SenecaServer.open_session()", DeprecationWarning,
                stacklevel=2)
            job_id, service = int(session), storage
            if len(legacy_storage) > 1 and batch_size is None:
                batch_size = legacy_storage[1]   # old positional form
            if not (isinstance(service, SenecaService) and legacy_storage
                    and batch_size):
                raise TypeError(
                    "expected DSIPipeline(session, storage) or legacy "
                    "DSIPipeline(job_id, service, storage, batch_size=N)")
            storage = legacy_storage[0]
            service.register_job(job_id, batch_size)
            self.session = Session(service, job_id, batch_size)
        self.svc: SenecaService = self.session.service
        self.storage = storage
        self.ds: SyntheticDataset = storage.dataset
        self.bs = self.session.batch_size
        self.pool = ThreadPoolExecutor(max_workers=n_workers)
        self.times = StageTimes()
        # telemetry feeds the adaptive repartition loop: per-stage EWMAs,
        # transfer bandwidths and per-form serve counts, aggregated across
        # every pipeline sharing the service
        self.telemetry = self.svc.telemetry
        self._n_workers = n_workers
        self.telemetry.add_concurrency(n_workers)
        self.rng = np.random.default_rng(seed + self.session.job_id)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _produce_sample(self, sid: int, epoch_tag: int) -> np.ndarray:
        """Run one sample through the remaining pipeline stages."""
        t_look = time.monotonic()
        form, value = self.session.lookup(sid)
        self.telemetry.record_serve(form)
        t0 = time.monotonic()
        if form == "augmented":
            self.times.fetch += time.monotonic() - t0
            self.telemetry.record_stage("fetch_cache", t0 - t_look)
            self.telemetry.record_bytes("cache", value.nbytes, t0 - t_look)
            return value
        if form == "decoded":
            img = value
            self.times.fetch += time.monotonic() - t0
            self.telemetry.record_stage("fetch_cache", t0 - t_look)
            self.telemetry.record_bytes("cache", img.nbytes, t0 - t_look)
        elif form == "encoded":
            enc = value
            self.times.fetch += time.monotonic() - t0
            self.telemetry.record_stage("fetch_cache", t0 - t_look)
            self.telemetry.record_bytes("cache", len(enc), t0 - t_look)
            t1 = time.monotonic()
            img = self.ds.decode(enc, sid)
            dt = time.monotonic() - t1
            self.times.decode += dt
            self.telemetry.record_stage("decode", dt)
            self.session.admit(sid, "decoded", img, img.nbytes)
        else:
            enc = self.storage.fetch(sid)
            dt = time.monotonic() - t0
            self.times.fetch += dt
            self.telemetry.record_stage("fetch_storage", dt)
            self.telemetry.record_bytes("storage", len(enc), dt)
            self.session.admit(sid, "encoded", enc, len(enc))
            t1 = time.monotonic()
            img = self.ds.decode(enc, sid)
            dt = time.monotonic() - t1
            self.times.decode += dt
            self.telemetry.record_stage("decode", dt)
            self.session.admit(sid, "decoded", img, img.nbytes)
        t2 = time.monotonic()
        aug_seed = (epoch_tag * 1_000_003 + sid) & 0x7FFFFFFF
        out = augment_np(img, self.ds.crop_hw,
                         np.random.default_rng(aug_seed))
        dt = time.monotonic() - t2
        self.times.augment += dt
        self.telemetry.record_stage("augment", dt)
        self.session.admit(sid, "augmented", out, out.nbytes)
        return out

    # ------------------------------------------------------------------
    def next_batch(self) -> Dict[str, np.ndarray]:
        ids, _forms = self.session.next_batch_ids()
        epoch_tag = self.session.epoch
        imgs = list(self.pool.map(
            lambda s: self._produce_sample(int(s), epoch_tag), ids))
        t0 = time.monotonic()
        batch = {
            "images": np.stack(imgs).astype(np.float32),
            "labels": np.asarray([self.ds.label(int(s)) for s in ids],
                                 np.int32),
        }
        dt = time.monotonic() - t0
        self.times.collate += dt
        self.telemetry.record_stage("collate", dt, n=len(ids))
        self.times.batches += 1
        self._process_refills()
        # adaptive-repartition tick: a fast no-op in "static"/"on-change"
        # modes; in "adaptive" this is where calibrated drift is checked
        self.svc.maybe_repartition()
        return batch

    def _process_refills(self, max_n: int = 32) -> None:
        """ODS step 5: repopulate evicted augmented slots with *fresh*
        random samples (unseen by every job), on the worker pool — the
        paper's background-refill thread.  Also proactively tops up free
        augmented capacity (cold start)."""
        work = self.svc.take_refill_work(max_n)
        spare = max_n - len(work)
        if spare > 0 and self.svc.tier_capacity("augmented"):
            free_slots = self.svc.tier_free_bytes("augmented") \
                // max(self.ds.augmented_bytes(), 1)
            if free_slots > 0:
                extra = self.svc.refill_candidates(min(spare, free_slots))
                work = np.concatenate([work, extra]) if len(work) else extra
        for sid in work:
            self.pool.submit(self._refill_one, int(sid))

    def _refill_one(self, sid: int) -> None:
        try:
            # a raced refill/admit may already have repopulated this slot;
            # peek() is stats-neutral so the check doesn't inflate misses
            if self.svc.cache.peek(sid)[0] == "augmented":
                return
            enc = self.storage.fetch(sid)
            img = self.ds.decode(enc, sid)
            out = augment_np(img, self.ds.crop_hw,
                             np.random.default_rng(sid ^ 0x5EED))
            self.session.admit(sid, "augmented", out, out.nbytes)
        except Exception:      # background worker must never kill serving
            pass

    # ------------------------------------------------------------------
    def start_prefetch(self) -> None:
        def run():
            while not self._stop.is_set():
                try:
                    self._q.put(self.next_batch(), timeout=0.5)
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def get(self, timeout: float = 60.0) -> Dict[str, np.ndarray]:
        return self._q.get(timeout=timeout)

    def stop(self) -> None:
        if not self._stop.is_set():
            self.telemetry.remove_concurrency(self._n_workers)
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)
        self.pool.shutdown(wait=False)
        self.session.close()
