"""The real (threaded) DSI pipeline: sampler -> fetch -> decode -> augment
-> collate -> device.

Plugs either a :class:`SenecaService` (MDP + ODS) or a naive baseline
sampler on top of the same storage + cache substrate, so the paper's
concurrency experiments run for real on CPU (examples/, tests/).
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.ods import AUGMENTED, DECODED, ENCODED, IN_STORAGE
from repro.core.seneca import SenecaService
from repro.data.augment import augment_np
from repro.data.storage import RemoteStorage
from repro.data.synthetic import SyntheticDataset


@dataclass
class StageTimes:
    fetch: float = 0.0
    decode: float = 0.0
    augment: float = 0.0
    collate: float = 0.0
    batches: int = 0

    def as_dict(self) -> Dict[str, float]:
        return {"fetch": self.fetch, "decode": self.decode,
                "augment": self.augment, "collate": self.collate,
                "batches": self.batches}


class DSIPipeline:
    """Per-job pipeline over a shared SenecaService + RemoteStorage."""

    def __init__(self, job_id: int, service: SenecaService,
                 storage: RemoteStorage, batch_size: int,
                 n_workers: int = 4, prefetch: int = 2, seed: int = 0):
        self.job_id = job_id
        self.svc = service
        self.storage = storage
        self.ds: SyntheticDataset = storage.dataset
        self.bs = batch_size
        self.pool = ThreadPoolExecutor(max_workers=n_workers)
        self.times = StageTimes()
        self.rng = np.random.default_rng(seed + job_id)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.svc.register_job(job_id, batch_size)

    # ------------------------------------------------------------------
    def _produce_sample(self, sid: int, epoch_tag: int) -> np.ndarray:
        """Run one sample through the remaining pipeline stages."""
        form, value = self.svc.lookup(sid)
        t0 = time.monotonic()
        if form == "augmented":
            self.times.fetch += time.monotonic() - t0
            return value
        if form == "decoded":
            img = value
            self.times.fetch += time.monotonic() - t0
        elif form == "encoded":
            enc = value
            self.times.fetch += time.monotonic() - t0
            t1 = time.monotonic()
            img = self.ds.decode(enc, sid)
            self.times.decode += time.monotonic() - t1
            self._maybe_admit_decoded(sid, img)
        else:
            enc = self.storage.fetch(sid)
            self.times.fetch += time.monotonic() - t0
            self._maybe_admit_encoded(sid, enc)
            t1 = time.monotonic()
            img = self.ds.decode(enc, sid)
            self.times.decode += time.monotonic() - t1
            self._maybe_admit_decoded(sid, img)
        t2 = time.monotonic()
        aug_seed = (epoch_tag * 1_000_003 + sid) & 0x7FFFFFFF
        out = augment_np(img, self.ds.crop_hw,
                         np.random.default_rng(aug_seed))
        self.times.augment += time.monotonic() - t2
        self._maybe_admit_augmented(sid, out)
        return out

    def _maybe_admit_encoded(self, sid: int, enc: bytes) -> None:
        part = self.svc.cache.parts["encoded"]
        if part.capacity and part.free_bytes >= len(enc):
            self.svc.admit(sid, "encoded", enc, len(enc))

    def _maybe_admit_decoded(self, sid: int, img: np.ndarray) -> None:
        part = self.svc.cache.parts["decoded"]
        if part.capacity and part.free_bytes >= img.nbytes:
            self.svc.admit(sid, "decoded", img, img.nbytes)

    def _maybe_admit_augmented(self, sid: int, out: np.ndarray) -> None:
        part = self.svc.cache.parts["augmented"]
        if part.capacity and part.free_bytes >= out.nbytes:
            self.svc.admit(sid, "augmented", out, out.nbytes)

    # ------------------------------------------------------------------
    def next_batch(self) -> Dict[str, np.ndarray]:
        ids, _forms = self.svc.next_batch_ids(self.job_id)
        epoch_tag = self.svc.ods.epoch.get(self.job_id, 0)
        imgs = list(self.pool.map(
            lambda s: self._produce_sample(int(s), epoch_tag), ids))
        t0 = time.monotonic()
        batch = {
            "images": np.stack(imgs).astype(np.float32),
            "labels": np.asarray([self.ds.label(int(s)) for s in ids],
                                 np.int32),
        }
        self.times.collate += time.monotonic() - t0
        self.times.batches += 1
        self._process_refills()
        return batch

    def _process_refills(self, max_n: int = 32) -> None:
        """ODS step 5: repopulate evicted augmented slots with *fresh*
        random samples (unseen by every job), on the worker pool — the
        paper's background-refill thread.  Also proactively tops up free
        augmented capacity (cold start)."""
        work = self.svc.take_refill_work(max_n)
        part = self.svc.cache.parts["augmented"]
        spare = max_n - len(work)
        if spare > 0 and part.capacity:
            free_slots = part.free_bytes // max(self.ds.augmented_bytes(), 1)
            if free_slots > 0:
                extra = self.svc.refill_candidates(min(spare, free_slots))
                work = np.concatenate([work, extra]) if len(work) else extra
        for sid in work:
            self.pool.submit(self._refill_one, int(sid))

    def _refill_one(self, sid: int) -> None:
        try:
            enc = self.storage.fetch(sid)
            img = self.ds.decode(enc, sid)
            out = augment_np(img, self.ds.crop_hw,
                             np.random.default_rng(sid ^ 0x5EED))
            self._maybe_admit_augmented(sid, out)
        except Exception:      # background worker must never kill serving
            pass

    # ------------------------------------------------------------------
    def start_prefetch(self) -> None:
        def run():
            while not self._stop.is_set():
                try:
                    self._q.put(self.next_batch(), timeout=0.5)
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def get(self, timeout: float = 60.0) -> Dict[str, np.ndarray]:
        return self._q.get(timeout=timeout)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)
        self.pool.shutdown(wait=False)
        self.svc.unregister_job(self.job_id)
