"""Random augmentations (random crop + horizontal flip + normalize).

Two equivalent implementations:
* numpy (host CPU — the paper-faithful placement), used by the pipeline;
* jnp (device), used by the Pallas-kernel path (kernels/augment) and as its
  oracle.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

MEAN = np.array([0.485, 0.456, 0.406], np.float32)
STD = np.array([0.229, 0.224, 0.225], np.float32)


def augment_np(img: np.ndarray, crop_hw: Tuple[int, int],
               rng: np.random.Generator) -> np.ndarray:
    """uint8 HWC -> float32 CHW-free (kept HWC) augmented tensor."""
    h, w, _ = img.shape
    ch, cw = crop_hw
    top = int(rng.integers(0, h - ch + 1))
    left = int(rng.integers(0, w - cw + 1))
    crop = img[top:top + ch, left:left + cw]
    if rng.integers(0, 2):
        crop = crop[:, ::-1]
    out = crop.astype(np.float32) / 255.0
    return (out - MEAN) / STD


def augment_batch_np(imgs: np.ndarray, crop_hw: Tuple[int, int],
                     seeds: np.ndarray) -> np.ndarray:
    out = np.empty((len(imgs), crop_hw[0], crop_hw[1], 3), np.float32)
    for i, (im, s) in enumerate(zip(imgs, seeds)):
        out[i] = augment_np(im, crop_hw, np.random.default_rng(int(s)))
    return out
