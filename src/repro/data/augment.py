"""Random augmentations (random crop + horizontal flip + normalize).

Two equivalent implementations:
* numpy (host CPU — the paper-faithful placement), used by the pipeline;
* jnp (device), used by the Pallas-kernel path (kernels/augment) and as its
  oracle.

Both paths derive the per-sample crop/flip parameters from the same
:func:`crop_flip_params` draw sequence, so a given seed produces the same
geometric transform no matter which backend executes the pixel math —
the parity contract pinned by tests/test_pipeline_executor.py.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

MEAN = np.array([0.485, 0.456, 0.406], np.float32)
STD = np.array([0.229, 0.224, 0.225], np.float32)


def crop_flip_params(rng: np.random.Generator, h: int, w: int,
                     ch: int, cw: int) -> Tuple[int, int, int]:
    """The canonical three-draw parameter sequence (top, left, flip)."""
    top = int(rng.integers(0, h - ch + 1))
    left = int(rng.integers(0, w - cw + 1))
    flip = int(rng.integers(0, 2))
    return top, left, flip


def derive_batch_params(hw: Tuple[int, int], crop_hw: Tuple[int, int],
                        seeds: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-sample (tops, lefts, flips) int32 arrays for ``seeds``.

    One fresh ``default_rng(seed)`` per sample, same draw order as
    :func:`augment_np` — this is what keeps the vectorized/Pallas path
    deterministic per *sample* (not per batch composition)."""
    h, w = hw
    ch, cw = crop_hw
    n = len(seeds)
    tops = np.empty(n, np.int32)
    lefts = np.empty(n, np.int32)
    flips = np.empty(n, np.int32)
    for i, s in enumerate(seeds):
        tops[i], lefts[i], flips[i] = crop_flip_params(
            np.random.default_rng(int(s)), h, w, ch, cw)
    return tops, lefts, flips


def augment_np(img: np.ndarray, crop_hw: Tuple[int, int],
               rng: np.random.Generator) -> np.ndarray:
    """uint8 HWC -> float32 CHW-free (kept HWC) augmented tensor."""
    h, w, _ = img.shape
    ch, cw = crop_hw
    top, left, flip = crop_flip_params(rng, h, w, ch, cw)
    crop = img[top:top + ch, left:left + cw]
    if flip:
        crop = crop[:, ::-1]
    out = crop.astype(np.float32) / 255.0
    return (out - MEAN) / STD


def augment_batch_np(imgs: np.ndarray, crop_hw: Tuple[int, int],
                     seeds: np.ndarray) -> np.ndarray:
    out = np.empty((len(imgs), crop_hw[0], crop_hw[1], 3), np.float32)
    for i, (im, s) in enumerate(zip(imgs, seeds)):
        out[i] = augment_np(im, crop_hw, np.random.default_rng(int(s)))
    return out
