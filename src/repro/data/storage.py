"""Simulated remote storage with a shared bandwidth budget.

A token-bucket limiter shared by all fetch threads reproduces the paper's
NFS bottleneck; with ``bandwidth=None`` the store is rate-unlimited (unit
tests).  Fetches return the dataset's encoded payload — the PRNG-backed
:class:`~repro.data.synthetic.SyntheticDataset` or the sharded on-disk
:class:`~repro.data.synthetic.FileDataset` (real file IO through the
same token bucket).

Counter discipline: ``BandwidthBudget.bytes_served`` and
``RemoteStorage.fetches`` are only ever mutated under the budget lock —
concurrent fetch workers previously raced the bare ``+=`` and dropped
increments, so benchmark fetch tallies undercounted under load.

Fault injection (``repro.faults``): :meth:`RemoteStorage.degrade` scales
the token-bucket rate (a storage-bandwidth collapse) and
:meth:`restore_bandwidth` undoes it; transient dataset IO errors are
retried a few times before propagating, with both degradations counted
for ``stats()``.

Clock correctness: the token bucket takes an optional pluggable
``clock`` (:class:`~repro.workload.clock.Clock`).  Without one the
historical behavior is byte-identical (``time.monotonic`` +
``time.sleep``) — but that bypasses a :class:`VirtualClock` entirely:
storage stalls then burn *wall* time on the calling job's turn and cost
zero *virtual* time, so virtual makespans and injected
bandwidth-collapse faults never shape the simulated timeline.  With a
clock, ``_available_at`` lives on the clock's timeline and the stall is
charged through :meth:`Clock.stall` on the calling thread's bound
participant ticket — :meth:`degrade`/:meth:`restore_bandwidth` then
take effect at the exact (virtual) instant they are applied, because
every subsequent ``consume`` prices its transfer off the clock's ``now``
and the post-change ``rate``.
"""
from __future__ import annotations

import threading
import time
from typing import Optional


class BandwidthBudget:
    def __init__(self, bytes_per_s: Optional[float], clock=None):
        self.rate = bytes_per_s
        self.base_rate = bytes_per_s     # pre-degradation rate
        self.clock = clock               # None -> wall time (historical)
        self.lock = threading.Lock()
        self._available_at = self._now()
        self.bytes_served = 0

    def _now(self) -> float:
        return time.monotonic() if self.clock is None else self.clock.now()

    def consume(self, nbytes: int) -> float:
        """Blocks until the transfer 'completes'; returns the stall time."""
        if self.rate is None:
            with self.lock:
                self.bytes_served += nbytes
            return 0.0
        with self.lock:
            now = self._now()
            start = max(now, self._available_at)
            self._available_at = start + nbytes / self.rate
            wait = self._available_at - now
            self.bytes_served += nbytes
        if wait > 0:
            if self.clock is None:
                time.sleep(wait)
            else:
                # charge the stall on the caller's clock participant:
                # under a VirtualClock this advances virtual time (and
                # yields the turn) instead of burning wall time
                self.clock.stall(wait)
        return max(wait, 0.0)


class RemoteStorage:
    def __init__(self, dataset, bandwidth: Optional[float] = None,
                 clock=None):
        self.dataset = dataset
        self.budget = BandwidthBudget(bandwidth, clock=clock)
        self.fetches = 0
        self.degraded = False
        self.degraded_fetches = 0        # fetches served while degraded
        self.io_retries = 0              # transient read errors retried

    # -- fault injection -------------------------------------------------
    def degrade(self, factor: float = 0.1) -> None:
        """Collapse the shared bandwidth to ``factor`` of the configured
        rate (an injected storage brownout).  No-op on unlimited
        stores beyond flipping the flag — there is no rate to scale."""
        if not factor > 0:
            raise ValueError(f"degrade factor must be > 0, got {factor}")
        with self.budget.lock:
            if self.budget.base_rate is not None:
                self.budget.rate = max(self.budget.base_rate * factor, 1.0)
            self.degraded = True

    def restore_bandwidth(self) -> None:
        with self.budget.lock:
            self.budget.rate = self.budget.base_rate
            self.degraded = False

    # -- data path ---------------------------------------------------------
    def fetch(self, sample_id: int) -> bytes:
        data = None
        for attempt in range(3):
            try:
                data = self.dataset.encoded(sample_id)
                break
            except OSError:
                # transient read failure (FileDataset under churn):
                # bounded retry before the pipeline sees the error
                with self.budget.lock:
                    self.io_retries += 1
                if attempt == 2:
                    raise
        self.budget.consume(len(data))
        with self.budget.lock:
            self.fetches += 1
            if self.degraded:
                self.degraded_fetches += 1
        return data
