"""Simulated remote storage with a shared bandwidth budget.

A token-bucket limiter shared by all fetch threads reproduces the paper's
NFS bottleneck; with ``bandwidth=None`` the store is rate-unlimited (unit
tests).  Fetches return the dataset's encoded payload — the PRNG-backed
:class:`~repro.data.synthetic.SyntheticDataset` or the sharded on-disk
:class:`~repro.data.synthetic.FileDataset` (real file IO through the
same token bucket).

Counter discipline: ``BandwidthBudget.bytes_served`` and
``RemoteStorage.fetches`` are only ever mutated under the budget lock —
concurrent fetch workers previously raced the bare ``+=`` and dropped
increments, so benchmark fetch tallies undercounted under load.
"""
from __future__ import annotations

import threading
import time
from typing import Optional


class BandwidthBudget:
    def __init__(self, bytes_per_s: Optional[float]):
        self.rate = bytes_per_s
        self.lock = threading.Lock()
        self._available_at = time.monotonic()
        self.bytes_served = 0

    def consume(self, nbytes: int) -> float:
        """Blocks until the transfer 'completes'; returns the stall time."""
        if self.rate is None:
            with self.lock:
                self.bytes_served += nbytes
            return 0.0
        with self.lock:
            now = time.monotonic()
            start = max(now, self._available_at)
            self._available_at = start + nbytes / self.rate
            wait = self._available_at - now
            self.bytes_served += nbytes
        if wait > 0:
            time.sleep(wait)
        return max(wait, 0.0)


class RemoteStorage:
    def __init__(self, dataset, bandwidth: Optional[float] = None):
        self.dataset = dataset
        self.budget = BandwidthBudget(bandwidth)
        self.fetches = 0

    def fetch(self, sample_id: int) -> bytes:
        data = self.dataset.encoded(sample_id)
        self.budget.consume(len(data))
        with self.budget.lock:
            self.fetches += 1
        return data
