"""Clock-driven fault injection over a live Seneca stack.

:class:`FaultInjector` turns a declarative :class:`~repro.faults.spec
.FaultSpec` trace into scheduled actions against a running
:class:`~repro.api.server.SenecaServer` + :class:`~repro.data.storage
.RemoteStorage` + :class:`~repro.workload.runner.WorkloadRunner`:

* it registers as one more participant on the workload clock, so under a
  ``VirtualClock`` every fault fires at an exact virtual time while all
  job threads are parked — the whole scenario, recovery included, is
  byte-for-byte reproducible;
* service/cache/storage faults (shard kill, spill corruption, bandwidth
  collapse) are applied directly on the injector's turn;
* job faults (worker crash, preemption) are *posted*: the owning job
  thread picks them up at its next batch boundary via
  :meth:`take_job_fault` and performs its own teardown/recovery —
  shared-state mutation stays on the registered thread that owns it.

Every injection and recovery increments a ``fault.<kind>`` /
``recovery.<kind>`` counter on the server's
:class:`~repro.api.telemetry.TelemetryAggregator`, which surfaces them
in ``stats()["faults"]``.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.faults.spec import FaultSpec

__all__ = ["FaultInjector", "corrupt_spill_files"]


def corrupt_spill_files(spill_dir: str, n_files: int) -> List[str]:
    """Truncate up to ``n_files`` spill files under ``spill_dir`` to a
    single byte (shorter than any codec's dtype×shape claim, so the next
    read raises inside the tier and degrades to a counted miss).

    Files are chosen in sorted path order — deterministic given the same
    cache state, which the VirtualClock turn discipline guarantees.
    """
    victims: List[str] = []
    for root, _dirs, files in sorted(os.walk(spill_dir)):
        for name in sorted(files):
            victims.append(os.path.join(root, name))
    victims = victims[:n_files]
    hit = []
    for path in victims:
        try:
            with open(path, "r+b") as f:
                f.truncate(1)
            hit.append(path)
        except OSError:
            continue
    return hit


class FaultInjector:
    """Replay a :class:`FaultSpec` trace on the workload clock.

    ``clock`` is duck-typed (``register``/``sleep_until``/``unregister``
    /``now``); ``None`` defaults to a fresh
    :class:`~repro.workload.clock.RealClock`.  ``server`` and
    ``storage`` may each be ``None`` when the trace contains no fault
    that needs them.
    """

    def __init__(self, specs: Sequence[FaultSpec], clock: Any = None,
                 *, server: Any = None, storage: Any = None):
        self.specs = list(specs)
        for s in self.specs:
            if not isinstance(s, FaultSpec):
                raise TypeError(f"expected FaultSpec, got {type(s).__name__}")
        if clock is None:
            from repro.workload.clock import RealClock
            clock = RealClock()
        self.clock = clock
        self.server = server
        self.storage = storage
        self._service = getattr(server, "service", server)
        needs_server = [s.kind for s in self.specs
                        if s.kind in ("shard-kill", "shard-restart",
                                      "spill-corrupt")]
        if needs_server and self._service is None:
            raise ValueError(f"faults {needs_server} need a shared server")
        if any(s.kind == "bandwidth-collapse" for s in self.specs) \
                and storage is None:
            raise ValueError("bandwidth-collapse needs the RemoteStorage")
        # timeline: the trace events plus derived auto-recovery events
        # (shard restart / bandwidth restore after duration_s), ordered
        # by (time, insertion sequence) for a deterministic tie-break
        timeline: List[Tuple[float, int, str, FaultSpec]] = []
        seq = 0
        for s in self.specs:
            timeline.append((s.at_s, seq, s.kind, s))
            seq += 1
            if s.duration_s > 0 and s.kind == "shard-kill":
                timeline.append((s.at_s + s.duration_s, seq,
                                 "shard-restart", s))
                seq += 1
            if s.duration_s > 0 and s.kind == "bandwidth-collapse":
                timeline.append((s.at_s + s.duration_s, seq,
                                 "bandwidth-restore", s))
                seq += 1
        self._timeline = sorted(timeline, key=lambda e: (e[0], e[1]))
        self._lock = threading.Lock()
        self._job_faults: Dict[str, List[FaultSpec]] = {}
        self._interrupt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._ticket: Optional[int] = None
        self._t0 = 0.0
        self.counts: Dict[str, int] = {}
        self.events: List[Dict] = []     # applied-event log (time-ordered)

    # ------------------------------------------------------------------
    def _count(self, channel: str, kind: str,
               telemetry: bool = True) -> None:
        key = f"{channel}.{kind}"
        with self._lock:
            self.counts[key] = self.counts.get(key, 0) + 1
        if not telemetry:        # the service layer already recorded it
            return
        agg = getattr(self._service, "telemetry", None)
        if agg is not None:
            agg.record_error(key)

    def record_recovery(self, kind: str) -> None:
        """Called by whoever performed a recovery the injector only
        posted (the runner, after a worker rebuild or re-admission)."""
        self._count("recovery", kind)

    # ------------------------------------------------------------------
    def start(self, t0: Optional[float] = None) -> None:
        """Register with the clock and begin replaying the trace.

        Under a VirtualClock, call this after every other participant
        has registered but before their threads block — exactly where
        the WorkloadRunner calls it.
        """
        if self._thread is not None:
            raise RuntimeError("injector already started")
        self._t0 = self.clock.now() if t0 is None else t0
        self._ticket = self.clock.register()
        self._thread = threading.Thread(target=self._run,
                                        name="fault-injector", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Interrupt any remaining sleep and join (idempotent).  Only
        call once the job outcomes no longer depend on pending events —
        the runner calls it after every job thread has been joined."""
        self._interrupt.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)

    def _run(self) -> None:
        try:
            for at, _seq, kind, spec in self._timeline:
                self.clock.sleep_until(self._ticket, self._t0 + at,
                                       interrupt=self._interrupt)
                if self._interrupt.is_set():
                    return
                try:
                    detail = self._apply(kind, spec)
                except Exception as e:      # noqa: BLE001 - logged, not fatal
                    detail = {"error": repr(e)}
                self.events.append({"t": self.clock.now() - self._t0,
                                    "kind": kind, **(detail or {})})
        finally:
            self.clock.unregister(self._ticket)

    # ------------------------------------------------------------------
    def take_job_fault(self, job: str) -> Optional[FaultSpec]:
        """Pop the earliest pending fault posted for ``job`` (runner
        polls this at each batch boundary)."""
        with self._lock:
            pending = self._job_faults.get(job)
            return pending.pop(0) if pending else None

    def _apply(self, kind: str, spec: FaultSpec) -> Dict:
        if kind in ("worker-crash", "preempt"):
            with self._lock:
                self._job_faults.setdefault(spec.job, []).append(spec)
            self._count("fault", kind)
            return {"job": spec.job}
        if kind == "shard-kill":
            self._service.fail_shard(spec.shard)
            self._count("fault", kind, telemetry=False)
            return {"shard": spec.shard}
        if kind == "shard-restart":
            self._service.restore_shard(spec.shard)
            self._count("recovery", kind, telemetry=False)
            return {"shard": spec.shard}
        if kind == "spill-corrupt":
            root = getattr(self._service.cache, "spill_dir", None) \
                or getattr(getattr(self._service, "cfg", None),
                           "spill_dir", None)
            hit = corrupt_spill_files(root, spec.n_files) if root else []
            self._count("fault", kind)
            return {"files": len(hit)}
        if kind == "bandwidth-collapse":
            self.storage.degrade(spec.factor)
            self._count("fault", kind)
            return {"factor": spec.factor}
        if kind == "bandwidth-restore":
            self.storage.restore_bandwidth()
            self._count("recovery", kind)
            return {}
        raise ValueError(f"unhandled fault kind {kind!r}")
