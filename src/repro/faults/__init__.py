"""Clock-driven fault injection and failover primitives.

Import-light by design: this package must be importable from
``repro.service`` (the cache client wires a :class:`LivenessRegistry`)
without dragging in ``repro.workload`` — see the module docstrings.
"""
from repro.faults.injector import FaultInjector, corrupt_spill_files
from repro.faults.liveness import LivenessRegistry
from repro.faults.spec import FAULT_KINDS, FaultSpec

__all__ = [
    "FAULT_KINDS",
    "FaultInjector",
    "FaultSpec",
    "LivenessRegistry",
    "corrupt_spill_files",
]
