"""Shard/worker liveness: the generalized heartbeat registry.

The seed's ``HeartbeatRegistry`` lived inside ``distributed/ft.py`` and
tracked trainer hosts against ``time.monotonic``.  This generalization
tracks any hashable member (host ids, shard ids, worker names) against an
injected clock, and distinguishes two failure signals:

* **expiry** — a member whose last beat is older than ``dead_after_s``
  (the classic heartbeat timeout);
* **explicit marks** — ``mark_dead`` from a fault injector or a
  transport that just watched a shard's pipe break.  Cleared by
  ``mark_alive`` on restart.

``clock`` is duck-typed: anything with a ``now() -> float`` works
(:class:`~repro.workload.clock.RealClock`, ``VirtualClock``, a test
fake).  The default reads ``time.monotonic`` directly so this module
stays import-light (no ``repro.workload`` dependency — the service layer
imports it from cache-client code).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Hashable, List, Optional, Set

__all__ = ["LivenessRegistry"]


class LivenessRegistry:
    """Clock-driven liveness over an arbitrary member set."""

    def __init__(self, dead_after_s: float = 10.0,
                 clock: Optional[Any] = None):
        self.dead_after_s = float(dead_after_s)
        self.clock = clock
        self.last_beat: Dict[Hashable, float] = {}
        self._down: Set[Hashable] = set()
        self._lock = threading.Lock()

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None \
            else time.monotonic()

    # ------------------------------------------------------------------
    def beat(self, member: Hashable, now: Optional[float] = None) -> None:
        with self._lock:
            self.last_beat[member] = now if now is not None else self._now()

    def mark_dead(self, member: Hashable) -> None:
        """Explicit failure signal (fault injection, broken transport)."""
        with self._lock:
            self._down.add(member)

    def mark_alive(self, member: Hashable) -> None:
        """Clear an explicit mark (member restarted) and refresh its beat."""
        with self._lock:
            self._down.discard(member)
            self.last_beat[member] = self._now()

    def forget(self, member: Hashable) -> None:
        with self._lock:
            self._down.discard(member)
            self.last_beat.pop(member, None)

    # ------------------------------------------------------------------
    def is_dead(self, member: Hashable) -> bool:
        """Explicitly marked dead (expiry is reported via :meth:`failed`
        — an expired member may just be slow, a marked one is known
        gone)."""
        with self._lock:
            return member in self._down

    def failed(self, now: Optional[float] = None) -> List[Hashable]:
        """Members explicitly dead or whose beat expired, stable order."""
        with self._lock:
            now = now if now is not None else self._now()
            out = [m for m, t in self.last_beat.items()
                   if m in self._down or now - t > self.dead_after_s]
            out += [m for m in sorted(self._down, key=repr)
                    if m not in self.last_beat]
            return out
