"""Declarative fault traces: what breaks, when, for how long.

A fault scenario is a list of :class:`FaultSpec` events scheduled on the
workload clock by :class:`~repro.faults.injector.FaultInjector`.  Under a
:class:`~repro.workload.clock.VirtualClock` the injector is one more
registered participant, so every fault fires at an exact virtual time
between job turns and the whole scenario — including recovery — is
byte-for-byte reproducible.

Kinds
-----
``worker-crash``
    The target job's pipeline workers die mid-run.  The runner tears the
    pipeline down (in-flight state lost) and rebuilds it on the same
    session — no sample is re-served, the session's sampler state was
    never lost.
``spill-corrupt``
    Truncate up to ``n_files`` spill-tier files on disk (deterministic:
    lexicographic order).  Subsequent reads degrade to misses and count
    ``io_errors``; nothing crashes.
``bandwidth-collapse``
    Scale the shared storage token-bucket rate by ``factor``; restored
    after ``duration_s`` (0 = permanent).
``shard-kill``
    Kill cache shard ``shard``: its key range fails over to storage
    (lookups miss, inserts drop) until the shard is restarted — after
    ``duration_s`` when > 0, or by an explicit ``shard-restart`` event.
``shard-restart``
    Restart a previously killed shard (cold: empty cache).
``preempt``
    Preempt the target job for ``duration_s`` seconds.  Under the
    runner's ``fault_policy="checkpoint"`` the session's sampler state
    is snapshotted and restored on re-admission (exactly-once-per-epoch
    coverage continues, nothing is re-preprocessed); under ``"restart"``
    the job loses all progress — the kill-and-restart-from-scratch
    baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

__all__ = ["FaultSpec", "FAULT_KINDS"]

FAULT_KINDS = ("worker-crash", "spill-corrupt", "bandwidth-collapse",
               "shard-kill", "shard-restart", "preempt")

_JOB_KINDS = ("worker-crash", "preempt")
_SHARD_KINDS = ("shard-kill", "shard-restart")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault event (times are trace-relative seconds)."""

    kind: str
    at_s: float
    job: Optional[str] = None        # target job name (worker-crash/preempt)
    shard: Optional[int] = None      # target shard id (shard-kill/-restart)
    duration_s: float = 0.0          # preempt dwell / auto-recovery window
    factor: float = 0.1              # bandwidth-collapse rate multiplier
    n_files: int = 2                 # spill files to corrupt

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")
        if self.at_s < 0:
            raise ValueError(f"{self.kind}: at_s must be >= 0")
        if self.duration_s < 0:
            raise ValueError(f"{self.kind}: duration_s must be >= 0")
        if self.kind in _JOB_KINDS and not self.job:
            raise ValueError(f"{self.kind} needs a target job name")
        if self.kind in _SHARD_KINDS and self.shard is None:
            raise ValueError(f"{self.kind} needs a target shard id")
        if self.kind == "bandwidth-collapse" and not self.factor > 0:
            raise ValueError("bandwidth-collapse: factor must be > 0")
        if self.kind == "spill-corrupt" and self.n_files < 1:
            raise ValueError("spill-corrupt: n_files must be >= 1")
