"""Fluid-flow simulator of the DSI pipeline.

Reproduces the paper's measured numbers without their hardware: per batch
round, each resource's busy time is ``demand / rate`` and the round takes
the *max* across resources (perfectly-overlapped pipeline, matching the
min-form of the closed-form model) — but the batch *composition* (which
tier serves each sample, ODS substitutions, refcount evictions, refills,
page-cache churn) is simulated mechanistically from real sampler + cache
state.  The closed-form model (Eqs. 1–9) and this simulator share only the
hardware constants, so Fig. 8's model-vs-"measured" correlation is a real
cross-validation.

All seven loaders of Table 7 are expressible as a :class:`LoaderSpec`.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.mdp import optimize
from repro.core.ods import EpochSampler, ODSState
from repro.core.perf_model import (DatasetProfile, HardwareProfile,
                                   JobProfile)

ENC, DEC, AUG = 1, 2, 3


@dataclass(frozen=True)
class LoaderSpec:
    """Knobs expressing the Table 7 loader matrix."""
    name: str
    sampling: str = "random"           # random | ods | quiver | importance
    cache_forms: Tuple[str, ...] = ("encoded",)
    shares_cache: bool = True          # False -> per-job private pipelines
    page_cache: bool = False           # LRU over encoded (PyTorch/DALI)
    cpu_scale: float = 1.0             # DALI pipelining gain / SHADE 1-thread
    gpu_offload: bool = False          # DALI-GPU: preprocessing on the GPU
    mdp_split: bool = False            # size tiers with MDP
    evict_refcount: bool = True        # Seneca augmented-tier eviction
    oversample: int = 1                # Quiver: 10x candidate requests
    split_override: Optional[Tuple[float, float, float]] = None
    # background refill thread speed: fraction of the augmented tier it can
    # repopulate per batch round (1/8 calibrated against Fig. 13's Azure
    # measurement; an unbounded thread saturates the hit rate at 1.0)
    refill_rate: float = 0.125


PYTORCH = LoaderSpec("pytorch", page_cache=True, shares_cache=False)
DALI_CPU = LoaderSpec("dali-cpu", page_cache=True, shares_cache=False,
                      cpu_scale=1.35)
DALI_GPU = LoaderSpec("dali-gpu", page_cache=True, shares_cache=False,
                      gpu_offload=True)
MINIO = LoaderSpec("minio", cache_forms=("encoded",), shares_cache=True)
QUIVER = LoaderSpec("quiver", sampling="quiver", oversample=10,
                    cache_forms=("encoded",))
SHADE = LoaderSpec("shade", sampling="importance", cpu_scale=1 / 8,
                   cache_forms=("encoded",))
MDP_ONLY = LoaderSpec("mdp", mdp_split=True,
                      cache_forms=("encoded", "decoded", "augmented"))
SENECA = LoaderSpec("seneca", sampling="ods", mdp_split=True,
                    cache_forms=("encoded", "decoded", "augmented"))

ALL_LOADERS = (PYTORCH, DALI_CPU, MINIO, QUIVER, SHADE, MDP_ONLY, SENECA)


@dataclass
class SimJob:
    job_id: int
    gpu_rate: float                  # samples/s this model trains at
    batch_size: int = 512
    epochs: int = 1
    arrival_s: float = 0.0
    # runtime
    served: int = 0
    done_at: Optional[float] = None
    dsi_busy: Dict[str, float] = field(default_factory=dict)


@dataclass
class SimResult:
    makespan: float
    total_samples: int
    throughput: float                # aggregate DSI samples/s
    hit_rate: float
    per_job_seconds: Dict[int, float]
    busy: Dict[str, float]           # resource busy seconds
    preprocess_ops: int              # decode+augment executions
    stable_epoch_s: Dict[int, float]
    first_epoch_s: Dict[int, float]


class DSISimulator:
    def __init__(self, hw: HardwareProfile, ds: DatasetProfile,
                 loader: LoaderSpec, cache_bytes: Optional[float] = None,
                 job_profile: Optional[JobProfile] = None, seed: int = 0,
                 aug_inflation: Optional[float] = None,
                 overlap: bool = True):
        self.hw = hw
        self.ds = ds
        self.loader = loader
        # overlap=True: round time = max resource time (pipelined).
        # overlap=False: per-form service classes serialize (the Eq. 9
        # weighted-mean discipline) — used by the Fig. 8 validation.
        self.overlap = overlap
        self.cache_bytes = cache_bytes if cache_bytes is not None \
            else hw.s_cache
        self.jobp = job_profile or JobProfile()
        self.rng = np.random.default_rng(seed)
        # per-form byte sizes (see DatasetProfile)
        if aug_inflation is not None:
            self.aug_b = self.dec_b = self.gpu_b = aug_inflation * ds.s_data
        elif ds.inflation:
            self.aug_b = self.dec_b = self.gpu_b = ds.inflation * ds.s_data
        else:
            self.aug_b, self.dec_b, self.gpu_b = (
                ds.augmented_bytes, ds.decoded_bytes, ds.gpu_bytes)
        N = ds.n_total

        # tier membership (bitmask arrays)
        self.in_enc = np.zeros(N, bool)
        self.in_dec = np.zeros(N, bool)
        self.in_aug = np.zeros(N, bool)
        self.refcount = np.zeros(N, np.int32)

        # partition capacities in samples
        if loader.split_override is not None:
            split = loader.split_override
        elif loader.mdp_split:
            hw2 = replace(hw, s_cache=float(self.cache_bytes))
            p = optimize(hw2, ds, self.jobp, step=0.02)
            split = (p.x_e, p.x_d, p.x_a)
        else:
            split = (1.0, 0.0, 0.0)
        self.split = split
        self.cap_enc = int(split[0] * self.cache_bytes / ds.s_data)
        self.cap_dec = int(split[1] * self.cache_bytes / self.dec_b)
        self.cap_aug = int(split[2] * self.cache_bytes / self.aug_b)
        if loader.page_cache:
            # page cache: all DRAM as one LRU over encoded files
            self.cap_enc = int(self.cache_bytes / ds.s_data)
            self.cap_dec = self.cap_aug = 0
        self._lru: List[int] = []       # page-cache LRU order (enc ids)

        # SHADE importance scores (sampling distribution precomputed)
        imp = self.rng.pareto(2.0, N) + 1.0
        self.importance_p = imp / imp.sum()

        # incremental tier occupancy counters (avoid O(N) scans per round)
        self.n_enc = 0
        self.n_dec = 0
        self.n_aug = 0

        self.hits = 0
        self.misses = 0
        self.preprocess_ops = 0

    # ------------------------------------------------------------------
    def _tier(self, ids: np.ndarray) -> np.ndarray:
        t = np.zeros(len(ids), np.int8)
        t[self.in_enc[ids]] = ENC
        t[self.in_dec[ids]] = DEC
        t[self.in_aug[ids]] = AUG
        return t

    def _admit(self, ids: np.ndarray) -> list:
        """Fill tiers (most-processed-first) up to capacity; page-cache LRU
        churns instead.  Returns ids admitted to the augmented tier."""
        aug_admitted = []
        if self.loader.page_cache:
            for sid in ids:
                if self.in_enc[sid]:
                    continue
                if self.n_enc >= max(self.cap_enc, 0) and self._lru:
                    victim = self._lru.pop(0)
                    self.in_enc[victim] = False
                    self.n_enc -= 1
                self.in_enc[sid] = True
                self.n_enc += 1
                self._lru.append(int(sid))
            return aug_admitted
        for sid in ids:
            if self.in_aug[sid] or self.in_dec[sid] or self.in_enc[sid]:
                continue
            if self.n_aug < self.cap_aug:
                self.in_aug[sid] = True
                self.refcount[sid] = 0
                self.n_aug += 1
                aug_admitted.append(int(sid))
            elif self.n_dec < self.cap_dec:
                self.in_dec[sid] = True
                self.n_dec += 1
            elif self.n_enc < self.cap_enc:
                self.in_enc[sid] = True
                self.n_enc += 1
        return aug_admitted

    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[SimJob], max_rounds: int = 100_000
            ) -> SimResult:
        N = self.ds.n_total
        n_jobs = len(jobs)
        ods = ODSState.create(N, seed=17)
        samplers: Dict[int, EpochSampler] = {}
        seen_priv: Dict[int, np.ndarray] = {}
        for j in jobs:
            ods.register_job(j.job_id)
            samplers[j.job_id] = EpochSampler(N, j.batch_size,
                                              11 + j.job_id)
            seen_priv[j.job_id] = np.zeros(N, bool)

        clock = 0.0
        busy = {k: 0.0 for k in ("storage", "cache_bw", "nic", "pcie",
                                 "cpu", "gpu")}
        epoch_marks: Dict[int, List[float]] = {j.job_id: [0.0] for j in jobs}
        total_served = 0
        S = self.ds.s_data
        a_b, d_b, g_b = self.aug_b, self.dec_b, self.gpu_b
        hw = self.hw
        n = hw.n_nodes

        rounds = 0
        while any(j.done_at is None for j in jobs) and rounds < max_rounds:
            rounds += 1
            active = [j for j in jobs
                      if j.done_at is None and j.arrival_s <= clock]
            if not active:
                future = [j.arrival_s for j in jobs if j.done_at is None]
                clock = min(future)
                continue

            demand = {k: 0.0 for k in busy}
            gpu_times: List[float] = []
            serial_times: List[float] = []
            for j in active:
                jid = j.job_id
                req = samplers[jid].next_request()
                if self.loader.sampling == "ods":
                    ods.status[:] = 0
                    ods.status[self.in_enc] = 1
                    ods.status[self.in_dec] = 2
                    ods.status[self.in_aug] = 3
                    ods.refcount[:] = self.refcount
                    batch, evicted = ods.sample_batch(jid, req)
                    self.refcount[:] = ods.refcount
                    # count tiers BEFORE applying evictions: a sample served
                    # from the augmented tier on its final use is a hit
                    tiers_pre = self._tier(batch)
                    if self.loader.evict_refcount:
                        if len(evicted):
                            was_aug = self.in_aug[evicted]
                            self.in_aug[evicted] = False
                            self.n_aug -= int(np.count_nonzero(was_aug))
                        # background refill (paper step 5): replace evicted
                        # slots 1:1; during the cold first epoch also fill
                        # empty capacity (initial population)
                        free = self.cap_aug - self.n_aug
                        warm_quota = j.batch_size \
                            if ods.epoch.get(jid, 0) == 0 else 0
                        rate_cap = max(
                            int(self.cap_aug * self.loader.refill_rate
                                / max(len(jobs), 1)), 1)
                        budget = min(free, rate_cap,
                                     max(len(evicted), warm_quota))
                        if budget > 0:
                            all_seen = np.ones(N, bool)
                            for bits in ods.seen.values():
                                all_seen &= bits
                            pool = np.flatnonzero(
                                ~self.in_aug & ~self.in_dec & ~self.in_enc
                                & ~all_seen)
                            take = min(budget, len(pool))
                            if take:
                                picks = self.rng.choice(pool, take,
                                                        replace=False)
                                fresh = self._admit(picks)
                                self.refcount[fresh] = 0
                                demand["storage"] += len(fresh) * S
                                demand["cpu"] += len(fresh) / (
                                    hw.t_da * self.loader.cpu_scale) / n
                elif self.loader.sampling == "quiver":
                    cand = samplers[jid].next_request()
                    for _ in range(self.loader.oversample - 1):
                        cand = np.concatenate(
                            [cand, samplers[jid].next_request()])
                    cached = cand[self._tier(cand) > 0]
                    un = cached[~seen_priv[jid][cached]][:j.batch_size]
                    rest = req[~np.isin(req, un)][:j.batch_size - len(un)]
                    batch = np.concatenate([un, rest])[:j.batch_size]
                    seen_priv[jid][batch] = True
                    if seen_priv[jid].sum() >= N - j.batch_size:
                        seen_priv[jid][:] = False
                    # over-sampling burns cache bandwidth on probes
                    demand["cache_bw"] += len(cand) * 0.002 * S
                elif self.loader.sampling == "importance":
                    batch = self.rng.choice(N, j.batch_size, replace=False,
                                            p=self.importance_p)
                else:
                    batch = req

                tiers = tiers_pre if self.loader.sampling == "ods" \
                    else self._tier(batch)
                n_aug = int(np.count_nonzero(tiers == AUG))
                n_dec = int(np.count_nonzero(tiers == DEC))
                n_enc = int(np.count_nonzero(tiers == ENC))
                n_sto = len(batch) - n_aug - n_dec - n_enc
                self.hits += n_aug + n_dec + n_enc
                self.misses += n_sto

                # resource demands (bytes / samples)
                demand["storage"] += n_sto * S
                demand["cache_bw"] += (n_enc * S + n_dec * d_b + n_aug * a_b)
                demand["nic"] += ((n_sto + n_enc) * S + n_dec * d_b
                                  + n_aug * a_b) / n
                demand["pcie"] += len(batch) * g_b / n
                if not self.overlap:
                    # Eq. 9 service discipline: each form-class runs to
                    # completion at its own min()-bound rate, serially
                    cls = [
                        max(n_sto * S / hw.b_storage,
                            n_sto * S / (n * hw.b_nic),
                            n_sto / (hw.t_da * self.loader.cpu_scale * n),
                            n_sto * g_b / (n * hw.b_pcie),
                            n_sto / (n * hw.t_gpu)),
                        max(n_enc * S / hw.b_cache,
                            n_enc * S / (n * hw.b_nic),
                            n_enc / (hw.t_da * self.loader.cpu_scale * n),
                            n_enc * g_b / (n * hw.b_pcie),
                            n_enc / (n * hw.t_gpu)),
                        max(n_dec * d_b / hw.b_cache,
                            n_dec * d_b / (n * hw.b_nic),
                            n_dec / (hw.t_a * self.loader.cpu_scale * n),
                            n_dec * g_b / (n * hw.b_pcie),
                            n_dec / (n * hw.t_gpu)),
                        max(n_aug * a_b / hw.b_cache,
                            n_aug * a_b / (n * hw.b_nic),
                            n_aug * g_b / (n * hw.b_pcie),
                            n_aug / (n * hw.t_gpu)),
                    ]
                    serial_times.append(sum(cls))
                cpu_da = (n_sto + n_enc) / self.loader.cpu_scale
                cpu_a = n_dec / self.loader.cpu_scale
                # decode executions (the Fig. 4b preprocessing count)
                self.preprocess_ops += n_sto + n_enc
                gpu_t = len(batch) / j.gpu_rate
                if self.loader.gpu_offload:
                    gpu_t += (n_sto + n_enc + n_dec) / (hw.t_gpu * 2.0)
                else:
                    demand["cpu"] += (cpu_da / hw.t_da + cpu_a / hw.t_a) / n
                gpu_times.append(gpu_t)

                # admissions: storage fetches may populate the cache; an
                # augmented tensor admitted via the serving path was
                # already consumed by jobs whose seen-bit is set — start
                # its refcount there so threshold eviction still fires
                fresh = self._admit(batch[tiers == 0])
                if fresh and self.loader.sampling == "ods":
                    fa = np.asarray(fresh)
                    cnt = np.zeros(len(fa), np.int32)
                    for bits in ods.seen.values():
                        cnt += bits[fa].astype(np.int32)
                    # all-seen admissions would pin a slot until epoch
                    # rollover without serving anyone: reject them
                    dead = fa[cnt >= len(ods.seen)]
                    if len(dead):
                        self.in_aug[dead] = False
                        self.n_aug -= len(dead)
                    live = fa[cnt < len(ods.seen)]
                    self.refcount[live] = cnt[cnt < len(ods.seen)]

                j.served += len(batch)
                total_served += len(batch)
                if j.served >= N * (len(epoch_marks[j.job_id])):
                    epoch_marks[j.job_id].append(clock)  # epoch boundary

            # round time = slowest resource (pipelined overlap); jobs train
            # on separate GPUs concurrently -> gpu term is the per-job max
            times = {
                "storage": demand["storage"] / hw.b_storage,
                "cache_bw": demand["cache_bw"] / hw.b_cache,
                "nic": demand["nic"] / hw.b_nic,
                "pcie": demand["pcie"] / hw.b_pcie,
                "cpu": demand["cpu"],
                "gpu": max(gpu_times) if gpu_times else 0.0,
            }
            if self.overlap:
                dt = max(times.values())
            else:
                dt = max(max(serial_times) if serial_times else 0.0,
                         times["gpu"])
            for k in busy:
                busy[k] += times[k]
            clock += dt

            for j in active:
                if j.served >= N * j.epochs:
                    j.done_at = clock

        makespan = max((j.done_at or clock) for j in jobs)
        per_job = {j.job_id: (j.done_at or clock) - j.arrival_s
                   for j in jobs}
        first_epoch = {}
        stable_epoch = {}
        for j in jobs:
            marks = epoch_marks[j.job_id]
            marks.append(j.done_at or clock)
            deltas = np.diff(marks)
            deltas = deltas[deltas > 0]
            if len(deltas):
                first_epoch[j.job_id] = float(deltas[0])
                stable_epoch[j.job_id] = float(
                    np.mean(deltas[1:]) if len(deltas) > 1 else deltas[0])
        hr = self.hits / max(self.hits + self.misses, 1)
        return SimResult(
            makespan=makespan, total_samples=total_served,
            throughput=total_served / max(makespan, 1e-9), hit_rate=hr,
            per_job_seconds=per_job, busy=busy,
            preprocess_ops=self.preprocess_ops,
            stable_epoch_s=stable_epoch, first_epoch_s=first_epoch)
