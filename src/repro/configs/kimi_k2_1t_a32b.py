"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert) vocab=163840,
MoE 384 routed experts top-8 (+1 shared).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163_840,
    head_dim=128,
    moe=MoEConfig(n_experts=384, top_k=8, n_shared=1, d_ff_expert=2048),
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2; unverified",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=512, head_dim=16,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=64))
