"""Architecture registry: ``--arch <id>`` resolution + default parallelism.

``get(arch_id)`` returns the full ModelConfig; ``get_reduced(arch_id)`` the
smoke-test config; ``default_parallelism(model, shape)`` encodes the layout
policy used by the dry-run and launchers (overridable from the CLI).
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (
    ALL_SHAPES, ModelConfig, ParallelismConfig, ShapeConfig, shape_applicable,
)

_MODULES: Dict[str, str] = {
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "llama3-405b": "repro.configs.llama3_405b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "vit-huge": "repro.configs.vit_huge",
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(k for k in _MODULES if k != "vit-huge")


def list_archs() -> List[str]:
    return list(_MODULES)


def get(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).reduced()


def cells(arch_ids=None) -> List[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    """All (arch x shape) cells with applicability flags (40 for the 10)."""
    out = []
    for aid in (arch_ids or ASSIGNED_ARCHS):
        m = get(aid)
        for s in ALL_SHAPES:
            ok, why = shape_applicable(m, s)
            out.append((m, s, ok, why))
    return out


# ---------------------------------------------------------------------------
# Default layout policy
# ---------------------------------------------------------------------------

# Archs whose param+optimizer footprint forces FSDP (ZeRO-style sharding of
# params/grads/opt-state over the 'data' axis) on a 16 GB/chip pod.
_FSDP_ARCHS = {"llama3-405b", "kimi-k2-1t-a32b", "qwen1.5-32b"}
# 8-bit optimizer state for the 1T arch (see DESIGN.md memory budget).
_OPT8_ARCHS = {"kimi-k2-1t-a32b"}


# Small archs whose 16-way TP is collective-bound at train_4k: the measured
# §Perf iterations (internvl2 0.09->0.63, mamba2 0.18->0.43) show pure-DP
# (batch over both axes, params replicated) removes the per-layer activation
# reductions.  Applied to the <=2.5B archs whose replicated params fit.
_PURE_DP_TRAIN = {"internvl2-2b", "mamba2-1.3b", "zamba2-1.2b",
                  "seamless-m4t-large-v2"}


def default_parallelism(model: ModelConfig, shape: ShapeConfig) -> ParallelismConfig:
    p = ParallelismConfig()
    if model.moe is not None:
        p = p.replace(ep=True)
    if shape.is_train:
        if model.name in _FSDP_ARCHS:
            p = p.replace(fsdp=True, remat="block", microbatches=4)
        if model.name in _OPT8_ARCHS:
            # §Perf kimi iterations: microbatches=1 avoids re-gathering
            # FSDP shards per microbatch; int8 moments use the structured
            # block layout (train/optimizer.py) so they inherit param specs
            p = p.replace(opt_state_dtype="int8", microbatches=1)
        elif model.name in _FSDP_ARCHS:
            p = p.replace(opt_state_dtype="bfloat16")
        if model.name in _PURE_DP_TRAIN and \
                shape.global_batch % 256 == 0:
            p = p.replace(tp=False, dp_over_model=True)
    else:
        # inference: no optimizer, no remat; batch=1 long decode replicates
        # data axis and uses sequence-parallel state sharding where possible.
        p = p.replace(remat="none", microbatches=1)
        if shape.name == "long_500k":
            p = p.replace(sp=True)
        if shape.name == "prefill_32k":
            p = p.replace(sp=True)   # sequence-shard activations for prefill
        if shape.kind == "prefill" and model.family == "ssm":
            # §Perf: sequence-parallel SSD replaces per-layer TP reductions
            # with ~4 MB state hand-offs (models/ssm_sp.py)
            p = p.replace(tp=False, sp_ssd=True)
    return p
