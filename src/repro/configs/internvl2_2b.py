"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The InternViT
frontend is a STUB: ``input_specs()`` supplies precomputed patch embeddings
(256 visual tokens per image) that are prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    head_dim=128,
    frontend="vision_stub",
    frontend_tokens=256,
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821; hf",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=16, frontend_tokens=8)
