"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes are :class:`ShapeConfig`; parallelism is a
:class:`ParallelismConfig`.  All configs are plain frozen dataclasses so they
hash, compare, and serialize trivially (the launcher dumps them to JSON next
to checkpoints).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------

FAMILIES = (
    "dense",      # decoder-only transformer
    "moe",        # decoder-only with routed experts
    "encdec",     # encoder-decoder (seamless)
    "ssm",        # attention-free state space (mamba2)
    "hybrid",     # mamba2 blocks + shared attention (zamba2)
    "vlm",        # vision frontend stub + LM backbone
    "audio",      # audio frontend stub + enc-dec backbone
    "encoder",    # encoder-only (vit_huge, paper's own)
)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    n_shared: int = 0         # always-on shared experts
    d_ff_expert: int = 0      # per-expert hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128        # N in SSD
    head_dim: int = 64        # P
    expand: int = 2           # d_inner = expand * d_model
    d_conv: int = 4
    chunk: int = 256          # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: a shared (weight-tied) attention block applied every k layers
    hybrid_attn_every: int = 0
    # sliding-window size used by hybrid attention at long context (0 = full)
    attn_window: int = 0
    # enc-dec
    n_encoder_layers: int = 0
    # frontends for [audio]/[vlm]: stub supplies precomputed embeddings
    frontend: str = "none"                 # none | audio_stub | vision_stub
    frontend_tokens: int = 0               # prefix embedding count per sample
    # encoder-only classification head (vit)
    n_classes: int = 0
    source: str = ""                       # provenance tag from the brief

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True when long_500k decode is admissible (SSM state or windowed)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return self.family != "encoder"

    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            n_h = d_in // s.head_dim
            # in_proj (z,x,B,C,dt) + out_proj + conv + norm + A,D
            per_layer = d * (2 * d_in + 2 * s.d_state + n_h) + d_in * d + \
                (d_in + 2 * s.d_state) * s.d_conv + d_in + 2 * n_h + d
            return emb + self.n_layers * per_layer
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        dense_ff = 3 * d * self.d_ff  # gated (silu) mlp
        norms = 2 * d
        if self.moe is not None:
            e = self.moe
            ff = 3 * d * e.d_ff_expert * (e.n_experts + e.n_shared) + d * e.n_experts
        else:
            ff = dense_ff
        per_layer = attn + ff + norms
        n = emb + self.n_layers * per_layer + d
        if self.family == "hybrid":
            # replace ff/attn estimate with mamba blocks + one shared attn block
            s = self.ssm
            d_in = s.expand * d
            n_h = d_in // s.head_dim
            mamba = d * (2 * d_in + 2 * s.d_state + n_h) + d_in * d + \
                (d_in + 2 * s.d_state) * s.d_conv + d_in + 2 * n_h + d
            shared = attn + dense_ff + norms
            n = emb + self.n_layers * mamba + shared + d
        if self.family == "encdec":
            # encoder layers (self-attn + ff) and decoder cross-attn
            enc = self.n_encoder_layers * (attn + dense_ff + norms)
            cross = self.n_layers * (attn + d)
            n += enc + cross
        if self.family == "encoder":
            n += d * self.n_classes
        return n

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: shared + top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        e = self.moe
        full = self.n_params()
        all_ff = 3 * d * e.d_ff_expert * (e.n_experts + e.n_shared)
        act_ff = 3 * d * e.d_ff_expert * (e.top_k + e.n_shared)
        return full - self.n_layers * (all_ff - act_ff)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable, reason-if-not) for an (arch, shape) cell."""
    if shape.kind == "decode" and not model.has_decoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""


# ---------------------------------------------------------------------------
# Parallelism
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelismConfig:
    """How a (arch x shape) cell is laid out on the mesh.

    Axes: optional leading 'pod' (DCN), 'data' (DP/FSDP/SP), 'model' (TP/EP).
    """
    dp: bool = True            # batch over ('pod','data')
    fsdp: bool = False         # params+opt state sharded over 'data' too
    tp: bool = True            # heads/ffn over 'model'
    ep: bool = False           # experts over 'model'
    sp: bool = False           # sequence over 'data' (long-context decode)
    remat: str = "none"        # none | block | full
    microbatches: int = 1      # gradient accumulation factor
    grad_compression: str = "none"   # none | int8_ef
    opt_state_dtype: str = "float32"  # float32 | bfloat16 | int8
    param_dtype: str = "bfloat16"
    # attention implementation: splash (pallas flash) | xla
    attn_impl: str = "xla"
    # pure-DP layout: replicate params and shard the batch over BOTH mesh
    # axes (tp must be off) — the right layout for small archs whose 16-way
    # TP is collective-bound (§Perf internvl2 iteration)
    dp_over_model: bool = False
    # sequence-parallel SSD prefill (SSM family): shard S over 'model',
    # replicate weights, hand states across ranks (models/ssm_sp.py)
    sp_ssd: bool = False
    # SSM out-projection comm strategy: all-gather the inner-sharded
    # activations instead of psum-ing the projected output — ~4x less wire
    # for ~7% redundant out-proj compute (§Perf zamba2 iteration)
    ssm_gather_out: bool = False

    def replace(self, **kw) -> "ParallelismConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelismConfig
    seed: int = 0

    def to_json(self) -> str:
        def enc(o):
            if dataclasses.is_dataclass(o):
                return dataclasses.asdict(o)
            raise TypeError(o)
        return json.dumps(self, default=enc, indent=2)
