"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066; hf].

28L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=102400.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408),
    rope_theta=10_000.0,
    source="arXiv:2401.06066; hf",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=96))
