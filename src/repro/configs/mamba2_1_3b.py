"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L d_model=2048 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2*d_model = 4096, head_dim=64 -> 64 SSD heads per layer.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
    source="arXiv:2405.21060; unverified",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=512,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2))
