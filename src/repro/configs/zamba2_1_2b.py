"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
A single weight-tied attention+MLP block is applied every 6 mamba layers
(Zamba2's shared-block design).  At long context the shared attention uses a
sliding window (4096) which keeps the arch sub-quadratic for long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2),
    hybrid_attn_every=6,
    attn_window=4096,
    rope_theta=10_000.0,
    source="arXiv:2411.15242; hf",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, ssm=SSMConfig(d_state=16, head_dim=16, expand=2),
        hybrid_attn_every=2, attn_window=64)
