"""vit-huge — the paper's own largest model (ViT-h, Fig. 15) [arXiv:2010.11929].

Encoder-only classifier: 32L d_model=1280 16H d_ff=5120, patch16 @ 224px
-> 196 patch tokens + [CLS], 1000 ImageNet classes (~632M params).
This is the config Seneca's image pipeline actually feeds in the paper's
evaluation; it exercises the encoder-only path (no decode shapes).
"""
from repro.configs.base import ModelConfig, ShapeConfig

CONFIG = ModelConfig(
    name="vit-huge",
    family="encoder",
    n_layers=32,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=0,
    n_classes=1000,
    frontend="vision_stub",
    frontend_tokens=197,     # 196 patches + CLS
    source="arXiv:2010.11929; hf",
)

# ViT trains on images, not 4k token streams: its own shape set.
TRAIN_224 = ShapeConfig("train_224", 197, 1024, "train")
SHAPES = (TRAIN_224,)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        n_classes=16, frontend_tokens=17)
