"""seamless-m4t-large-v2 [audio] — enc-dec multimodal [arXiv:2308.11596; hf].

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.  Encoder and decoder
each get 24 layers (speech encoder + text decoder, per the M4T v2 layout).
The audio frontend (w2v-BERT conformer feature extractor) is a STUB:
``input_specs()`` supplies precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    frontend="audio_stub",
    frontend_tokens=0,      # encoder consumes frame embeddings directly
    rope_theta=10_000.0,
    source="arXiv:2308.11596; hf",
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512)
