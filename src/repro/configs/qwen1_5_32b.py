"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27_392,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=512)
