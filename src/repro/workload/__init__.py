"""Multi-job workload execution over the live Seneca stack.

:class:`WorkloadRunner` admits a trace of :class:`JobSpec`\\ s against a
:class:`~repro.api.server.SenecaServer` (shared cache) or a per-job
server factory (private baseline), pacing each job's pipeline with a
rate-limited consumer that emulates GPU ingest.  The :class:`Clock`
abstraction makes concurrency reproducible: :class:`RealClock` is wall
time, :class:`VirtualClock` serializes job threads deterministically so
multi-job interleavings are byte-for-byte repeatable in tests.

Open-loop serving (docs/API.md "Open-loop serving & SLOs"):
:class:`OpenLoopGenerator` replays a trace-driven arrival schedule
(:func:`poisson_arrivals` / :func:`bursty_arrivals` /
:func:`diurnal_arrivals`) against the session API with per-request
p50/p99/p999 latency accounting and SLO-aware admission control.

See docs/API.md "Multi-job workloads".
"""
from repro.workload.clock import Clock, RealClock, VirtualClock
from repro.workload.openloop import (ARRIVAL_PROCESSES, OpenLoopGenerator,
                                     RequestResult, ServeResult,
                                     bursty_arrivals, diurnal_arrivals,
                                     make_arrivals, poisson_arrivals,
                                     quantile)
from repro.workload.runner import (JobResult, JobSpec, WorkloadResult,
                                   WorkloadRunner, deterministic_runner)
from repro.workload.samplers import (REQUEST_SAMPLERS, PhaseShiftSampler,
                                     ZipfianSampler, make_request_sampler)

__all__ = [
    "Clock", "RealClock", "VirtualClock",
    "JobSpec", "JobResult", "WorkloadResult", "WorkloadRunner",
    "deterministic_runner",
    "ZipfianSampler", "PhaseShiftSampler", "make_request_sampler",
    "REQUEST_SAMPLERS",
    "OpenLoopGenerator", "RequestResult", "ServeResult",
    "ARRIVAL_PROCESSES", "poisson_arrivals", "bursty_arrivals",
    "diurnal_arrivals", "make_arrivals", "quantile",
]
