"""Multi-job workload execution over the live Seneca stack.

:class:`WorkloadRunner` admits a trace of :class:`JobSpec`\\ s against a
:class:`~repro.api.server.SenecaServer` (shared cache) or a per-job
server factory (private baseline), pacing each job's pipeline with a
rate-limited consumer that emulates GPU ingest.  The :class:`Clock`
abstraction makes concurrency reproducible: :class:`RealClock` is wall
time, :class:`VirtualClock` serializes job threads deterministically so
multi-job interleavings are byte-for-byte repeatable in tests.

See docs/API.md "Multi-job workloads".
"""
from repro.workload.clock import Clock, RealClock, VirtualClock
from repro.workload.runner import (JobResult, JobSpec, WorkloadResult,
                                   WorkloadRunner, deterministic_runner)

__all__ = [
    "Clock", "RealClock", "VirtualClock",
    "JobSpec", "JobResult", "WorkloadResult", "WorkloadRunner",
    "deterministic_runner",
]
