"""Open-loop serving over the session API: trace-driven request
arrivals, per-request latency accounting, SLO-aware admission control.

Closed-loop training traces (:mod:`repro.workload.runner`) only ask for
the next batch when the previous one is consumed — the pipeline can
never fall behind, only slow down.  Production preprocessing is
*open-loop*: requests arrive whether or not the pipeline is ready
(tf.data's service framing), and what matters is per-request **tail
latency** with a per-phase breakdown (CoorDL's data-stalls analysis) —
queue wait, fetch, decode, augment — not aggregate throughput.

Three pieces:

* **Arrival processes** — :func:`poisson_arrivals`,
  :func:`bursty_arrivals` (on/off modulated Poisson) and
  :func:`diurnal_arrivals` (sinusoidal rate, Lewis-Shedler thinning).
  Whole schedules are generated up front from one seeded
  ``numpy.random.default_rng``, so a schedule is byte-for-byte
  reproducible regardless of the clock that later replays it.
* **:class:`OpenLoopGenerator`** — replays a schedule against a live
  :class:`~repro.api.server.SenecaServer` + ``RemoteStorage``: a
  generator participant enqueues requests at their arrival instants,
  ``n_workers`` worker participants serve them through the session
  (lookup → fetch → decode → augment, admitting produced forms back to
  the shared cache).  Under a
  :class:`~repro.workload.clock.VirtualClock` the whole run is
  deterministic: the generator registers *first* (lowest ticket wins
  wake-time ties, so an arrival always lands before the service work at
  the same instant), workers bind their tickets so storage stalls from
  a clock-aware token bucket charge *virtual* time, and optional
  ``phase_costs`` model decode/augment service time on the clock
  (compute alone costs zero virtual seconds).
* **SLO admission control** — with an :class:`~repro.api.server.SLO`
  each arrival's queue wait is estimated as ``backlog x service-time
  EWMA / workers`` and the request is admitted at a *work level*: full
  (augmented), degraded (skip augment), encoded (skip decode+augment),
  or shed outright past ``shed_frac`` / ``max_queue``.  Degrading caps
  the work a request may buy, never the quality of an already-cached
  form.  Every decision is counted in
  ``stats()["telemetry"]["requests"]``.

See docs/API.md "Open-loop serving & SLOs".
"""
from __future__ import annotations

import logging
import math
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.api.server import SLO, SenecaServer
from repro.api.telemetry import quantile
from repro.data.augment import augment_np
from repro.data.pipeline import _aug_seed
from repro.data.storage import RemoteStorage
from repro.workload.clock import Clock, RealClock

log = logging.getLogger(__name__)

__all__ = ["poisson_arrivals", "bursty_arrivals", "diurnal_arrivals",
           "make_arrivals", "ARRIVAL_PROCESSES", "RequestResult",
           "ServeResult", "OpenLoopGenerator", "quantile"]

ARRIVAL_PROCESSES = ("poisson", "bursty", "diurnal")

#: admission work levels, most→least work; index = level
_LEVEL_FORMS = ("encoded", "decoded", "augmented")
_OUTCOME = {"augmented": "served", "decoded": "degraded",
            "encoded": "encoded"}


# ---------------------------------------------------------------------------
# arrival schedules (all offsets from 0, sorted, one seeded RNG)
# ---------------------------------------------------------------------------
def poisson_arrivals(rate: float, n: int, seed: int = 0) -> np.ndarray:
    """``n`` Poisson arrivals at ``rate`` req/s: i.i.d. exponential
    inter-arrival gaps, cumulatively summed."""
    if not rate > 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _thinned(rate_fn: Callable[[float], float], rate_max: float, n: int,
             seed: int) -> np.ndarray:
    """Lewis–Shedler thinning: candidate arrivals at ``rate_max``,
    accepted with probability ``rate_fn(t) / rate_max`` — an exact
    sampler for any bounded time-varying rate."""
    rng = np.random.default_rng(seed)
    out = np.empty(n, np.float64)
    t, i = 0.0, 0
    while i < n:
        t += rng.exponential(1.0 / rate_max)
        if rng.random() * rate_max <= rate_fn(t):
            out[i] = t
            i += 1
    return out


def bursty_arrivals(rate: float, n: int, seed: int = 0, *,
                    burst_factor: float = 3.0, duty: float = 0.25,
                    period_s: float = 4.0) -> np.ndarray:
    """On/off modulated Poisson with long-run mean ``rate``: for the
    first ``duty`` fraction of every ``period_s`` window the
    instantaneous rate is ``burst_factor x rate``; the off-phase rate is
    solved so the window mean stays ``rate`` (requires
    ``burst_factor < 1/duty``)."""
    if not rate > 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if not 0 < duty < 1:
        raise ValueError(f"duty must be in (0, 1), got {duty}")
    if not 1 <= burst_factor < 1.0 / duty:
        raise ValueError(f"burst_factor must be in [1, 1/duty={1/duty:g}), "
                         f"got {burst_factor}")
    hi = rate * burst_factor
    lo = rate * (1.0 - duty * burst_factor) / (1.0 - duty)

    def rate_fn(t: float) -> float:
        return hi if (t % period_s) < duty * period_s else lo

    return _thinned(rate_fn, hi, n, seed)


def diurnal_arrivals(rate: float, n: int, seed: int = 0, *,
                     depth: float = 0.8,
                     period_s: float = 60.0) -> np.ndarray:
    """Sinusoidally modulated Poisson (a compressed day/night cycle):
    instantaneous rate ``rate * (1 + depth * sin(2*pi*t/period_s))``,
    long-run mean ``rate``."""
    if not rate > 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if not 0 <= depth < 1:
        raise ValueError(f"depth must be in [0, 1), got {depth}")

    def rate_fn(t: float) -> float:
        return rate * (1.0 + depth * math.sin(2.0 * math.pi * t / period_s))

    return _thinned(rate_fn, rate * (1.0 + depth), n, seed)


def make_arrivals(process: str, rate: float, n: int, seed: int = 0,
                  **kw) -> np.ndarray:
    """Dispatch on ``process`` name (:data:`ARRIVAL_PROCESSES`)."""
    fns = {"poisson": poisson_arrivals, "bursty": bursty_arrivals,
           "diurnal": diurnal_arrivals}
    if process not in fns:
        raise ValueError(f"unknown arrival process {process!r}; expected "
                         f"one of {ARRIVAL_PROCESSES}")
    return fns[process](rate, n, seed, **kw)


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------
@dataclass
class RequestResult:
    """One request's outcome + per-phase latency (seconds, trace time
    relative to the run's t0).  Shed requests have zero latency and no
    phases — they never entered the queue."""

    req_id: int
    sample_id: int
    arrival_s: float
    outcome: str = "shed"        # "served"|"degraded"|"encoded"|"shed"
    level: int = 2               # admitted work level (2 full .. 0 encoded)
    form: Optional[str] = None   # cache form that answered the lookup
    start_s: float = 0.0         # dequeue instant (service start)
    end_s: float = 0.0
    queue_s: float = 0.0
    fetch_s: float = 0.0
    decode_s: float = 0.0
    augment_s: float = 0.0

    @property
    def total_s(self) -> float:
        return self.end_s - self.arrival_s

    def phases(self) -> Dict[str, float]:
        """Phase breakdown with zero-duration phases omitted (an
        augmented cache hit has no decode/augment phase at all)."""
        out = {"queue": self.queue_s, "fetch": self.fetch_s}
        if self.decode_s > 0:
            out["decode"] = self.decode_s
        if self.augment_s > 0:
            out["augment"] = self.augment_s
        return out


@dataclass
class ServeResult:
    """Outcome of one :meth:`OpenLoopGenerator.run` call."""

    requests: List[RequestResult]
    makespan_s: float            # last completion (trace time, from t0)
    clock: str                   # clock name ("real" | "virtual")
    offered_rate: float          # n_arrivals / last arrival offset
    wall_s: float = 0.0          # host seconds the run() call took
    slo: Optional[SLO] = None
    counts: Dict[str, int] = field(default_factory=dict)

    @property
    def completed(self) -> List[RequestResult]:
        return [r for r in self.requests if r.outcome != "shed"]

    @property
    def shed(self) -> int:
        return self.counts.get("shed", 0)

    @property
    def degraded(self) -> int:
        return self.counts.get("degraded", 0)

    def latencies(self) -> List[float]:
        return [r.total_s for r in self.completed]

    def percentiles(self) -> Dict[str, float]:
        """p50/p99/p999 of completed-request latency (exact
        nearest-rank — see :func:`repro.api.telemetry.quantile`)."""
        lat = self.latencies()
        if not lat:
            return {}
        return {"p50": quantile(lat, 0.50), "p99": quantile(lat, 0.99),
                "p999": quantile(lat, 0.999)}

    def phase_percentiles(self) -> Dict[str, Dict[str, float]]:
        per: Dict[str, List[float]] = {}
        for r in self.completed:
            for phase, dt in r.phases().items():
                per.setdefault(phase, []).append(dt)
        return {p: {"p50": quantile(v, 0.50), "p99": quantile(v, 0.99)}
                for p, v in per.items()}

    def as_dict(self) -> Dict[str, object]:
        return {
            "n_requests": len(self.requests),
            "counts": dict(self.counts),
            "offered_rate": self.offered_rate,
            "makespan_s": self.makespan_s,
            "clock": self.clock,
            "latency_s": self.percentiles(),
            "phase_latency_s": self.phase_percentiles(),
        }


# ---------------------------------------------------------------------------
# the generator
# ---------------------------------------------------------------------------
_FROM_CONFIG = object()          # sentinel: inherit SenecaConfig.slo


class OpenLoopGenerator:
    """Replay an arrival schedule against a live server with per-request
    latency accounting and (optionally) SLO-aware admission control.

    ``slo`` defaults to the server's ``SenecaConfig.slo``; pass ``None``
    explicitly for the uncontrolled baseline (requests queue without
    bound).  ``phase_costs`` maps ``"decode"`` / ``"augment"`` to modeled
    per-request service seconds charged on the clock — required for
    meaningful queueing under a :class:`VirtualClock`, where compute is
    free; leave unset on a :class:`RealClock` to measure real compute.
    ``consumer`` is called as ``consumer(result, value)`` with every
    completed request's payload — the hook the resident inference model
    (``launch/serve.py --open-loop``) feeds from.
    """

    def __init__(self, server: SenecaServer, storage: RemoteStorage, *,
                 clock: Optional[Clock] = None, slo=_FROM_CONFIG,
                 n_workers: int = 2, seed: int = 0,
                 phase_costs: Optional[Dict[str, float]] = None,
                 consumer: Optional[Callable] = None):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.server = server
        self.storage = storage
        self.clock = clock or RealClock()
        self.slo: Optional[SLO] = server.service.cfg.slo \
            if slo is _FROM_CONFIG else slo
        self.n_workers = n_workers
        self.seed = seed
        self.phase_costs = dict(phase_costs) if phase_costs else {}
        self.consumer = consumer
        if self.clock.deterministic:
            transport = getattr(server.service.cache, "transport_name",
                                "sim")
            if transport != "sim":
                raise ValueError(
                    "deterministic VirtualClock serving requires the 'sim' "
                    f"shard transport, not {transport!r} (process shards "
                    "reply on wall-clock OS scheduling)")
        self._lock = threading.Lock()
        self._stop = threading.Event()

    # ------------------------------------------------------------------
    def cancel(self) -> None:
        self._stop.set()

    def run(self, arrivals: Sequence[float], *,
            sample_ids: Optional[Sequence[int]] = None,
            raise_on_error: bool = True) -> ServeResult:
        """Replay ``arrivals`` (offsets from run start, sorted) and join.

        ``sample_ids`` assigns the sample each request asks for; by
        default they are drawn uniformly from the dataset with the
        generator's seed (schedule-independent, so the same ids pair
        with the same arrival offsets across runs).
        """
        arrivals = np.asarray(list(arrivals), np.float64)
        if arrivals.size == 0:
            raise ValueError("empty arrival schedule")
        if np.any(np.diff(arrivals) < 0):
            raise ValueError("arrival offsets must be sorted ascending")
        n_total = self.storage.dataset.n_samples
        if sample_ids is None:
            sids = np.random.default_rng(self.seed).integers(
                0, n_total, size=arrivals.size)
        else:
            sids = np.asarray(list(sample_ids), np.int64)
            if sids.size != arrivals.size:
                raise ValueError(
                    f"sample_ids has {sids.size} entries for "
                    f"{arrivals.size} arrivals")
        self._stop.clear()
        self._errors: List[BaseException] = []
        self._queue: "deque" = deque()
        self._results: List[Optional[RequestResult]] = [None] * arrivals.size
        self._next_arrival: Optional[float] = None
        self._gen_done = False
        self._svc_ewma: Optional[float] = None

        import time as _time
        wall0 = _time.monotonic()
        # clock-correct control plane for the whole run (repartition
        # cooldowns tick in trace time)
        self.server.service.set_clock(self.clock)
        sess = self.server.open_session(batch_size=1)
        t0 = self.clock.now()
        self._next_arrival = t0 + float(arrivals[0])
        # the generator registers FIRST: at equal wake times the lowest
        # ticket runs first, so an arrival always lands in the queue
        # before a worker waking at the same instant looks for it
        gen_ticket = self.clock.register()
        worker_tickets = [self.clock.register()
                          for _ in range(self.n_workers)]
        threads = [threading.Thread(
            target=self._generate, args=(gen_ticket, t0, arrivals, sids),
            name="openloop-gen", daemon=True)]
        threads += [threading.Thread(
            target=self._worker, args=(ticket, t0, sess),
            name=f"openloop-w{i}", daemon=True)
            for i, ticket in enumerate(worker_tickets)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        end_now = self.clock.now()
        sess.close()

        requests = [r for r in self._results if r is not None]
        counts = {o: 0 for o in ("served", "degraded", "encoded", "shed")}
        for r in requests:
            counts[r.outcome] += 1
        out = ServeResult(
            requests=requests,
            makespan_s=max([r.end_s for r in requests] + [end_now - t0]),
            clock=self.clock.name,
            offered_rate=float(arrivals.size / max(arrivals[-1], 1e-9)),
            wall_s=_time.monotonic() - wall0,
            slo=self.slo, counts=counts)
        if self._errors and raise_on_error:
            raise RuntimeError(
                f"open-loop serving failed: {self._errors[0]!r}"
            ) from self._errors[0]
        return out

    # ------------------------------------------------------------------
    def _admit_locked(self, backlog: int) -> Optional[int]:
        """Admission decision for one arrival (lock held): the work
        level (2 full, 1 skip-augment, 0 encoded-only) or None = shed.
        The wait estimate is ``backlog x service-time EWMA / workers`` —
        the queueing delay this request would see if admitted now."""
        slo = self.slo
        if slo is None:
            return 2
        if backlog >= slo.max_queue:
            return None
        est = 0.0 if self._svc_ewma is None \
            else backlog * self._svc_ewma / self.n_workers
        target = slo.p99_target_s
        if est > slo.shed_frac * target:
            return None
        if est > slo.encode_frac * target:
            return 0
        if est > slo.degrade_frac * target:
            return 1
        return 2

    def _generate(self, ticket: int, t0: float, arrivals: np.ndarray,
                  sids: np.ndarray) -> None:
        tel = self.server.service.telemetry
        try:
            for i in range(arrivals.size):
                now = self.clock.sleep_until(ticket, t0 + float(arrivals[i]),
                                             interrupt=self._stop)
                if self._stop.is_set():
                    return
                with self._lock:
                    self._next_arrival = t0 + float(arrivals[i + 1]) \
                        if i + 1 < arrivals.size else None
                    level = self._admit_locked(len(self._queue))
                    if level is None:
                        res = RequestResult(
                            req_id=i, sample_id=int(sids[i]),
                            arrival_s=now - t0, outcome="shed",
                            start_s=now - t0, end_s=now - t0)
                        self._results[i] = res
                    else:
                        self._queue.append((i, int(sids[i]), now, level))
                if level is None:
                    tel.record_request("shed")
        except BaseException as e:      # noqa: BLE001 - reported after join
            with self._lock:
                self._errors.append(e)
            self._stop.set()
            log.warning("open-loop generator failed", exc_info=True)
        finally:
            with self._lock:
                self._gen_done = True
            self.clock.unregister(ticket)

    def _worker(self, ticket: int, t0: float, sess) -> None:
        # bind so storage token-bucket stalls (and modeled phase costs)
        # charge this participant's clock turn, not wall time
        self.clock.bind(ticket)
        try:
            while not self._stop.is_set():
                with self._lock:
                    item = self._queue.popleft() if self._queue else None
                    gen_done = self._gen_done
                    next_arr = self._next_arrival
                if item is None:
                    if gen_done:
                        return
                    now = self.clock.now()
                    # idle until the published next arrival; the small
                    # fallback step avoids a zero-advance livelock when
                    # that instant is already here but not yet enqueued
                    wake = next_arr if next_arr is not None \
                        and next_arr > now else now + 1e-3
                    self.clock.sleep_until(ticket, wake,
                                           interrupt=self._stop)
                    continue
                self._serve(item, t0, sess)
        except BaseException as e:      # noqa: BLE001 - reported after join
            with self._lock:
                self._errors.append(e)
            self._stop.set()
            log.warning("open-loop worker failed", exc_info=True)
        finally:
            self.clock.unbind()
            self.clock.unregister(ticket)

    # ------------------------------------------------------------------
    def _charge(self, phase: str) -> None:
        """Charge a modeled per-request service cost for ``phase`` on
        the clock (no-op unless configured in ``phase_costs``)."""
        cost = self.phase_costs.get(phase, 0.0)
        if cost > 0:
            self.clock.stall(cost, interrupt=self._stop)

    def _serve(self, item, t0: float, sess) -> None:
        """One request through lookup → fetch → decode → augment, capped
        at its admitted work level; admits produced forms back to the
        shared cache exactly like the closed-loop pipeline."""
        req_id, sid, arrival_abs, level = item
        now = self.clock.now
        ds = self.storage.dataset
        tel = self.server.service.telemetry
        start = now()
        form, value, _tier = sess.lookup_tiered(sid)
        tel.record_serve(form)
        fetch_s = decode_s = augment_s = 0.0
        if form is None:
            enc = self.storage.fetch(sid)       # clock-aware stall
            sess.admit(sid, "encoded", enc, len(enc))
            cur_form, cur = "encoded", enc
        else:
            cur_form, cur = form, value
        fetch_s = now() - start
        # work up the form ladder, but never past the admitted level —
        # a cache hit above the level is served as-is (degrading caps
        # work, not the quality of what is already cached)
        if level >= 1 and cur_form == "encoded":
            t1 = now()
            self._charge("decode")
            img = ds.decode(cur, sid)
            sess.admit(sid, "decoded", img, img.nbytes)
            decode_s = now() - t1
            cur_form, cur = "decoded", img
        if level >= 2 and cur_form == "decoded":
            t2 = now()
            self._charge("augment")
            out = augment_np(cur, ds.crop_hw, np.random.default_rng(
                _aug_seed(sess.epoch, sid)))
            sess.admit(sid, "augmented", out, out.nbytes)
            augment_s = now() - t2
            cur_form, cur = "augmented", out
        end = now()
        res = RequestResult(
            req_id=req_id, sample_id=sid, arrival_s=arrival_abs - t0,
            outcome=_OUTCOME[cur_form], level=level, form=form,
            start_s=start - t0, end_s=end - t0,
            queue_s=start - arrival_abs, fetch_s=fetch_s,
            decode_s=decode_s, augment_s=augment_s)
        with self._lock:
            self._results[req_id] = res
            # service-time EWMA feeding the admission wait estimate
            svc = end - start
            self._svc_ewma = svc if self._svc_ewma is None \
                else 0.2 * svc + 0.8 * self._svc_ewma
        tel.record_request(res.outcome, total_s=res.total_s,
                           phases=res.phases())
        if self.consumer is not None:
            self.consumer(res, cur)
