"""Live multi-job workload runner over one (or many) SenecaServer(s).

The paper's headline result — concurrent jobs sharing one Seneca cache
finish *faster* than jobs on private caches — previously existed in this
repo only inside the fluid simulator (:mod:`repro.sim.desim`).  This
module runs it for real: a :class:`WorkloadRunner` admits a trace of
:class:`JobSpec`\\ s (arrival time, epochs, batch size, GPU ingest rate)
against a live :class:`~repro.api.server.SenecaServer`, running each
job's :class:`~repro.data.pipeline.DSIPipeline` on its own thread with a
rate-limited consumer emulating GPU ingest (the pipeline's
``consume_hook``), per-job epoch/makespan accounting and graceful
join/cancel.  Session arrival/departure flows through
``SenecaServer.open_session`` / ``Session.close`` and therefore triggers
the :class:`~repro.api.server.RepartitionController` exactly as any
other client would.

Determinism: pass ``clock=VirtualClock()`` and the runner serializes the
job threads through the clock's turn discipline (one participant runs at
a time, released in ``(wake_time, ticket)`` order) and pins each job to
the per-sample executor with one worker and synchronous refills — two
runs of the same trace then produce byte-identical per-job sample-id
sequences and identical makespans, which is what keeps the concurrency
tests non-flaky.  The runner installs its clock on the shared service
(adaptive-repartition cooldowns tick in trace time) and binds each job
thread's participant ticket on the clock, so a clock-aware
``RemoteStorage(ds, bandwidth, clock=clock)`` charges storage stalls as
*virtual* time on the job's own turn — bandwidth then shapes virtual
makespans exactly as it would wall ones.

Shared vs private: construct with ``server=`` for the paper's
many-jobs-one-cache scenario, or ``server_factory=`` to give every job
its own private server (the baseline side of
``benchmarks/fig_live_makespan.py``).
"""
from __future__ import annotations

import logging
import math
import threading
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional,
                    Sequence)

from repro.data.pipeline import DSIPipeline, EXECUTORS
from repro.data.storage import RemoteStorage
from repro.faults.injector import FaultInjector
from repro.faults.spec import FaultSpec
from repro.workload.clock import Clock, RealClock, VirtualClock

if TYPE_CHECKING:                      # runtime import is deferred: the
    from repro.api.server import SenecaServer   # api package re-exports
                                       # workload names, so a module-level
                                       # import here would be circular

log = logging.getLogger(__name__)

__all__ = ["JobSpec", "JobResult", "WorkloadResult", "WorkloadRunner"]


@dataclass(frozen=True)
class JobSpec:
    """One training job in a workload trace."""

    name: str
    arrival_s: float = 0.0       # trace time the job enters the system
    epochs: int = 1              # full dataset passes to consume
    batch_size: int = 32
    gpu_rate: float = math.inf   # samples/s the emulated GPU ingests
    executor: str = "per-sample"  # DSIPipeline executor
    n_workers: int = 2           # pipeline workers (1 under VirtualClock)
    max_batches: Optional[int] = None   # optional cap below epochs*N/B
    # request-stream shape: None = uniform epoch permutation (the
    # historical default); "zipfian" / "phase-shift" (or any name in
    # repro.workload.samplers.REQUEST_SAMPLERS) = skewed/shifting
    # traffic for this job only
    sampler: Optional[str] = None

    def __post_init__(self) -> None:
        if self.epochs < 1:
            raise ValueError(f"job {self.name!r}: epochs must be >= 1")
        if self.batch_size < 1:
            raise ValueError(f"job {self.name!r}: batch_size must be >= 1")
        if not self.gpu_rate > 0:
            raise ValueError(f"job {self.name!r}: gpu_rate must be > 0")
        if self.arrival_s < 0:
            raise ValueError(f"job {self.name!r}: arrival_s must be >= 0")
        if self.executor not in EXECUTORS:
            # fail at spec construction, not inside a job thread after
            # the session has already been opened on the shared server
            raise ValueError(f"job {self.name!r}: unknown executor "
                             f"{self.executor!r}; expected one of "
                             f"{EXECUTORS}")
        if self.sampler is not None:
            from repro.workload.samplers import REQUEST_SAMPLERS
            if self.sampler not in REQUEST_SAMPLERS:
                raise ValueError(
                    f"job {self.name!r}: unknown sampler "
                    f"{self.sampler!r}; expected one of "
                    f"{tuple(sorted(REQUEST_SAMPLERS))}")


@dataclass
class JobResult:
    """Per-job accounting (all times relative to the run's t0)."""

    spec: JobSpec
    job_id: Optional[int] = None     # session job id (shared-server runs)
    start_s: float = 0.0             # first moment the job ran (>= arrival)
    end_s: float = 0.0               # after its last batch's ingest pacing
    samples: int = 0
    batches: int = 0
    epoch_ends: List[float] = field(default_factory=list)
    sample_ids: List[int] = field(default_factory=list)  # slot order
    error: Optional[str] = None
    cancelled: bool = False
    stats: Optional[Dict] = None     # private-server runs: stats at close
    preemptions: int = 0             # injected preempt + re-admission count
    worker_restarts: int = 0         # injected worker-crash recoveries

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s

    @property
    def epochs_completed(self) -> int:
        return len(self.epoch_ends)

    @property
    def ok(self) -> bool:
        return self.error is None and not self.cancelled


@dataclass
class WorkloadResult:
    """Outcome of one :meth:`WorkloadRunner.run` call."""

    jobs: List[JobResult]
    makespan: float                  # max job end (trace time, from t0)
    clock: str                       # clock name ("real" | "virtual")
    wall_s: float                    # host seconds the run() call took
    stats: Optional[Dict] = None     # shared server stats at quiesce
    timed_out: bool = False          # run(timeout=) expired, jobs cut short

    @property
    def shard_stats(self) -> Optional[List[Dict]]:
        """Per-shard cache stats when the server ran a sharded data
        plane (``SenecaConfig(shards=N)``), else None."""
        return (self.stats or {}).get("shards")

    @property
    def total_samples(self) -> int:
        return sum(j.samples for j in self.jobs)

    @property
    def ok(self) -> bool:
        return all(j.ok for j in self.jobs)

    def job(self, name: str) -> JobResult:
        for j in self.jobs:
            if j.spec.name == name:
                return j
        raise KeyError(name)


class _IngestPacer:
    """Rate-limited consumer emulating GPU ingest, installed as the
    pipeline's ``consume_hook``: every produced batch charges
    ``batch_size / gpu_rate`` seconds on the workload clock before the
    job asks for the next one.  Under a :class:`VirtualClock` this is
    also the job's scheduling point — even an infinite-rate job yields
    its turn here once per batch."""

    def __init__(self, clock: Clock, ticket: int, rate: float,
                 start_at: float, interrupt: threading.Event):
        self.clock = clock
        self.ticket = ticket
        self.rate = rate
        self.now = start_at          # the job's own clock position
        self._interrupt = interrupt

    def __call__(self, batch) -> None:
        dt = len(batch["ids"]) / self.rate if math.isfinite(self.rate) \
            else 0.0
        self.now = self.clock.sleep_until(self.ticket, self.now + dt,
                                          interrupt=self._interrupt)


class WorkloadRunner:
    """Admit a trace of jobs against live Seneca server(s) and account
    per-job epochs + workload makespan.

    Exactly one of ``server`` (shared cache — the paper's scenario) or
    ``server_factory`` (a private server per job — the baseline) must be
    given.  ``storage`` is shared by every job either way, so both modes
    contend for the same token-bucket bandwidth.
    """

    def __init__(self, server: Optional[SenecaServer] = None,
                 storage: Optional[RemoteStorage] = None, *,
                 server_factory: Optional[
                     Callable[[JobSpec], SenecaServer]] = None,
                 clock: Optional[Clock] = None,
                 record_ids: bool = True,
                 seed: int = 0,
                 faults: Optional[Sequence[FaultSpec]] = None,
                 fault_policy: str = "checkpoint"):
        if (server is None) == (server_factory is None):
            raise ValueError("WorkloadRunner needs exactly one of server= "
                             "(shared cache) or server_factory= (private "
                             "per-job caches)")
        if storage is None:
            raise TypeError("WorkloadRunner needs a shared RemoteStorage")
        if fault_policy not in ("checkpoint", "restart"):
            raise ValueError("fault_policy must be 'checkpoint' (snapshot "
                             "sampler state, restore on re-admission) or "
                             "'restart' (naive: lose all progress), got "
                             f"{fault_policy!r}")
        self.server = server
        self.server_factory = server_factory
        self.storage = storage
        self.clock = clock or RealClock()
        self.record_ids = record_ids
        self.seed = seed
        self.faults = list(faults) if faults else []
        self.fault_policy = fault_policy
        self._injector: Optional[FaultInjector] = None
        self._stop = threading.Event()
        if isinstance(self.clock, VirtualClock) and server is not None:
            # determinism only holds for in-process shards: the sim
            # transport runs shard calls synchronously on the calling
            # job's turn, while process shards answer on OS scheduling
            transport = getattr(server.service.cache, "transport_name", "sim")
            if transport != "sim":
                raise ValueError(
                    "deterministic VirtualClock runs require the 'sim' "
                    f"shard transport, not {transport!r} (process shards "
                    "reply on wall-clock OS scheduling)")

    # ------------------------------------------------------------------
    def cancel(self) -> None:
        """Ask every job thread to stop after its current batch; virtual
        clock sleeps are interrupted too, so ``run()`` unblocks."""
        self._stop.set()

    # ------------------------------------------------------------------
    def run(self, trace: Sequence[JobSpec], *,
            timeout: Optional[float] = None,
            raise_on_error: bool = True) -> WorkloadResult:
        """Run the trace to completion (or cancellation) and join.

        ``timeout`` bounds the host-time wait for the whole workload;
        on expiry the remaining jobs are cancelled and joined.  With
        ``raise_on_error`` (default) a job-thread failure raises after
        every thread has been joined; otherwise it is reported in the
        corresponding :class:`JobResult.error`.
        """
        trace = list(trace)
        if not trace:
            raise ValueError("empty workload trace")
        names = [s.name for s in trace]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate job names in trace: {names}")
        deterministic = self.clock.deterministic
        if deterministic:
            bad = [s.name for s in trace if s.executor != "per-sample"]
            if bad:
                raise ValueError(
                    f"virtual-clock runs require executor='per-sample' "
                    f"(jobs {bad} use the stage-parallel executor, whose "
                    f"free-running stage threads would race past the "
                    f"clock's turn discipline)")
        if self.faults:
            bad_jobs = [f.job for f in self.faults
                        if f.job is not None and f.job not in names]
            if bad_jobs:
                raise ValueError(f"fault trace targets unknown jobs "
                                 f"{bad_jobs}; trace has {names}")
            if any(f.shard is not None for f in self.faults) and (
                    self.server is None
                    or not hasattr(self.server.service, "fail_shard")
                    or not hasattr(self.server.service.cache,
                                   "kill_shard")):
                raise ValueError("shard faults need a shared sharded "
                                 "server (SenecaConfig(shards=N))")
        self._stop.clear()

        import time as _time
        wall0 = _time.monotonic()
        if self.server is not None:
            # clock-correct control plane: the adaptive repartition
            # cooldown ticks in trace time, not host CPU time
            self.server.service.set_clock(self.clock)
        t0 = self.clock.now()
        results = [JobResult(spec=s) for s in trace]
        # register every participant BEFORE any thread starts: the
        # virtual clock must know the full roster or it would dispatch
        # the first sleeper alone
        tickets = [self.clock.register() for _ in trace]
        # the fault injector registers as one more participant, so its
        # events fire at exact virtual times between job turns
        self._injector = None
        if self.faults:
            self._injector = FaultInjector(
                self.faults, self.clock,
                server=self.server, storage=self.storage)
            self._injector.start(t0)
        threads = []
        for spec, ticket, res in zip(trace, tickets, results):
            t = threading.Thread(
                target=self._run_job, args=(spec, ticket, res, t0),
                name=f"workload-{spec.name}", daemon=True)
            threads.append(t)
        for t in threads:
            t.start()

        deadline = None if timeout is None else wall0 + timeout
        for t in threads:
            t.join(None if deadline is None
                   else max(deadline - _time.monotonic(), 0.0))
        timed_out = any(t.is_alive() for t in threads)
        if timed_out:
            self.cancel()
            for t in threads:
                t.join(timeout=10.0)
        still = [t.name for t in threads if t.is_alive()]
        if still:       # pragma: no cover - join() hanging is a bug
            raise RuntimeError(f"workload threads failed to join: {still}")
        if self._injector is not None:
            self._injector.stop()   # every job joined: drain + unregister

        out = WorkloadResult(
            jobs=results,
            makespan=max(r.end_s for r in results),
            clock=self.clock.name,
            wall_s=_time.monotonic() - wall0,
            stats=self.server.stats() if self.server is not None else None,
            timed_out=timed_out)
        errors = [(r.spec.name, r.error) for r in results if r.error]
        if errors and raise_on_error:
            raise RuntimeError(f"workload jobs failed: {errors}")
        if timed_out and raise_on_error:
            # a truncated run must not masquerade as a complete one:
            # callers consuming makespans (benchmarks) would otherwise
            # compare numbers capped at the timeout
            cut = [r.spec.name for r in results if r.cancelled]
            raise RuntimeError(
                f"workload timed out after {timeout}s; cancelled jobs "
                f"{cut} (pass raise_on_error=False to inspect the "
                f"truncated WorkloadResult)")
        return out

    # ------------------------------------------------------------------
    def _run_job(self, spec: JobSpec, ticket: int, res: JobResult,
                 t0: float) -> None:
        """One job's thread body: wait for arrival, open a session, pump
        batches through a rate-limited consumer, account epochs."""
        from repro.api.server import SessionClosed   # deferred: cycle
        pipe = None
        sess = None
        private_server = None
        # bind this thread to its participant ticket so components deep
        # in the data path (the storage token bucket) can charge stalls
        # on the clock without a ticket threaded through their signatures
        self.clock.bind(ticket)
        try:
            now = self.clock.sleep_until(ticket, t0 + spec.arrival_s,
                                         interrupt=self._stop)
            res.start_s = now - t0
            if self._stop.is_set():
                res.cancelled = True
                res.end_s = res.start_s
                return
            if self.server_factory is not None:
                private_server = self.server_factory(spec)
                server = private_server
                server.service.set_clock(self.clock)
            else:
                server = self.server
            sess = server.open_session(batch_size=spec.batch_size,
                                       sampler=spec.sampler)
            res.job_id = sess.job_id
            pacer = _IngestPacer(self.clock, ticket, spec.gpu_rate,
                                 start_at=now, interrupt=self._stop)
            deterministic = self.clock.deterministic

            def build_pipe() -> DSIPipeline:
                return DSIPipeline(
                    sess, self.storage,
                    n_workers=1 if deterministic else spec.n_workers,
                    executor=spec.executor, seed=self.seed,
                    consume_hook=pacer, sync_refills=deterministic,
                    clock=self.clock)

            pipe = build_pipe()
            n = self.storage.dataset.n_samples
            # the samplers serve whole batches and re-permute early when
            # the batch size does not divide the dataset, so one "epoch"
            # is the largest whole-batch pass — targeting that keeps
            # sample counts exact (no final-batch overshoot) and epoch
            # accounting aligned with what the sampler actually does
            epoch_size = (n // spec.batch_size) * spec.batch_size
            if epoch_size == 0:
                raise ValueError(
                    f"job {spec.name!r}: batch_size {spec.batch_size} "
                    f"exceeds the dataset ({n} samples)")
            target = spec.epochs * epoch_size
            if spec.max_batches is not None:
                target = min(target, spec.max_batches * spec.batch_size)
            injector = self._injector
            while res.samples < target and not self._stop.is_set():
                fault = injector.take_job_fault(spec.name) \
                    if injector is not None else None
                if fault is not None:
                    if fault.kind == "worker-crash":
                        # pipeline workers died: in-flight batches are
                        # lost but the session (sampler state) survives —
                        # rebuild the pipeline on the same session
                        pipe.stop(close_session=False)
                        pipe = build_pipe()
                        res.worker_restarts += 1
                        injector.record_recovery("worker-restart")
                    elif fault.kind == "preempt":
                        snap = sess.checkpoint_state() \
                            if self.fault_policy == "checkpoint" else None
                        pipe.stop(close_session=False)
                        sess.close()   # the job leaves the system
                        now = self.clock.sleep_until(
                            ticket, pacer.now + fault.duration_s,
                            interrupt=self._stop)
                        if self._stop.is_set():
                            res.cancelled = True
                            res.end_s = now - t0
                            return
                        # re-admission: fresh session; under the
                        # checkpoint policy the sampler resumes exactly
                        # where it left off, under the naive-restart
                        # baseline all progress is lost
                        sess = server.open_session(
                            batch_size=spec.batch_size,
                            sampler=spec.sampler)
                        res.job_id = sess.job_id
                        if snap is not None:
                            sess.restore_state(snap)
                        else:
                            res.samples = 0
                            res.batches = 0
                            res.sample_ids.clear()
                            res.epoch_ends.clear()
                        pacer.now = now
                        pipe = build_pipe()
                        res.preemptions += 1
                        injector.record_recovery("preempt-readmit")
                    continue
                try:
                    batch = pipe.next_batch()   # pacer sleeps inside
                except SessionClosed:
                    break
                res.samples += len(batch["ids"])
                res.batches += 1
                if self.record_ids:
                    res.sample_ids.extend(int(x) for x in batch["ids"])
                while res.samples >= epoch_size * (len(res.epoch_ends)
                                                   + 1):
                    res.epoch_ends.append(pacer.now - t0)
            res.cancelled = self._stop.is_set() and res.samples < target
            res.end_s = pacer.now - t0
        except Exception as e:      # noqa: BLE001 - reported, not lost
            res.error = repr(e)
            res.end_s = self.clock.now() - t0
            log.warning("workload job %s failed", spec.name, exc_info=True)
        finally:
            try:
                if pipe is not None:
                    pipe.stop()     # closes the session too
                elif sess is not None:
                    # pipeline construction failed after the session was
                    # opened: close it or the shared server carries a
                    # phantom job forever (inflated eviction threshold,
                    # ghost session in the repartition trigger)
                    sess.close()
                if private_server is not None:
                    res.stats = private_server.stats()
                    private_server.close()
            except Exception:       # noqa: BLE001 - teardown best-effort
                log.warning("workload job %s teardown failed", spec.name,
                            exc_info=True)
            finally:
                # ALWAYS release the clock turn or peers deadlock
                self.clock.unbind()
                self.clock.unregister(ticket)


# re-exported convenience: a short way to say "the deterministic setup"
def deterministic_runner(server: SenecaServer, storage: RemoteStorage,
                         **kw) -> WorkloadRunner:
    """A :class:`WorkloadRunner` on a fresh :class:`VirtualClock` (the
    reproducible-concurrency configuration used by the tests)."""
    return WorkloadRunner(server, storage, clock=VirtualClock(), **kw)
