"""Pluggable clocks for multi-job workload execution.

The :class:`~repro.workload.runner.WorkloadRunner` paces every job thread
(arrival times, GPU-ingest rate limiting) through one of these clocks:

* :class:`RealClock` — wall time (``time.monotonic`` + interruptible
  sleeps).  What a live deployment uses.
* :class:`VirtualClock` — a deterministic discrete-event clock.  Every
  participant (one per job thread) registers up front; time advances only
  when *all* registered participants are blocked in
  :meth:`~VirtualClock.sleep_until`, and exactly **one** participant is
  released per advance — the one with the smallest ``(wake_time,
  ticket)`` pair.  Between two of its own sleeps a participant therefore
  runs *alone*: shared-state interleavings (cache admissions, ODS
  sampling, the service RNG) are serialized in a reproducible order, and
  two runs of the same trace produce byte-identical sample sequences and
  makespans.  Compute costs zero virtual time; only explicit sleeps
  advance the clock, so virtual makespans measure the *pacing* schedule
  (arrivals + ingest rates), not host CPU speed.

The contract a participant must honor for determinism to hold: do all
shared-state work between ``sleep_until`` calls on the registered thread
itself (no unregistered helper threads racing past the turn boundary).
The runner enforces this by pinning virtual-clock jobs to the per-sample
pipeline executor with a single worker and synchronous refills.
"""
from __future__ import annotations

import itertools
import threading
import time
from abc import ABC, abstractmethod
from typing import Dict, Optional

__all__ = ["Clock", "RealClock", "VirtualClock"]


class Clock(ABC):
    """Time source + cooperative scheduler used by workload job threads.

    ``register()`` hands out a participant ticket; every timed wait goes
    through ``sleep_until(ticket, wake_at)`` which returns the (possibly
    virtual) time at which the caller resumed.  ``interrupt`` is an
    optional :class:`threading.Event` that aborts the wait early
    (cancellation) — after it fires, determinism guarantees end but no
    participant may deadlock.
    """

    name: str = "clock"
    deterministic: bool = False

    @abstractmethod
    def now(self) -> float: ...

    @abstractmethod
    def register(self) -> int: ...

    @abstractmethod
    def unregister(self, ticket: int) -> None: ...

    @abstractmethod
    def sleep_until(self, ticket: int, wake_at: float,
                    interrupt: Optional[threading.Event] = None) -> float:
        ...

    def sleep(self, ticket: int, seconds: float,
              interrupt: Optional[threading.Event] = None) -> float:
        """Relative-time convenience over :meth:`sleep_until`."""
        return self.sleep_until(ticket, self.now() + max(seconds, 0.0),
                                interrupt=interrupt)

    # -- thread-ticket binding -----------------------------------------
    # Components deep inside the data path (the storage token bucket,
    # the open-loop serving phases) need to charge blocking stalls on
    # the clock without having a ticket threaded through every call
    # signature.  A participant thread binds its ticket once
    # (``bind``), and anything it later calls can ``stall(seconds)``:
    # with a bound ticket the stall is a real scheduled sleep (virtual
    # time advances deterministically); unbound threads fall back to a
    # wall sleep on non-deterministic clocks and a no-op on
    # deterministic ones (an unregistered thread cannot take a turn —
    # the VirtualClock contract pins all timed work to participants).

    def _bound(self) -> Dict[int, int]:
        d = getattr(self, "_thread_tickets", None)
        if d is None:
            d = self._thread_tickets = {}
        return d

    def bind(self, ticket: int) -> None:
        """Associate the calling thread with ``ticket`` for ``stall``."""
        self._bound()[threading.get_ident()] = ticket

    def unbind(self) -> None:
        self._bound().pop(threading.get_ident(), None)

    def bound_ticket(self) -> Optional[int]:
        return self._bound().get(threading.get_ident())

    def stall(self, seconds: float,
              interrupt: Optional[threading.Event] = None) -> float:
        """Charge a blocking stall of ``seconds`` on the calling
        thread's bound ticket; returns the clock time after the stall.
        """
        if seconds <= 0:
            return self.now()
        ticket = self.bound_ticket()
        if ticket is not None:
            return self.sleep(ticket, seconds, interrupt=interrupt)
        if not self.deterministic:
            if interrupt is not None:
                interrupt.wait(seconds)
            else:
                time.sleep(seconds)
        return self.now()


class RealClock(Clock):
    """Wall-clock time; sleeps are interruptible via the cancel event."""

    name = "real"
    deterministic = False

    def __init__(self) -> None:
        self._tickets = itertools.count()

    def now(self) -> float:
        return time.monotonic()

    def register(self) -> int:
        return next(self._tickets)

    def unregister(self, ticket: int) -> None:
        pass

    def sleep_until(self, ticket: int, wake_at: float,
                    interrupt: Optional[threading.Event] = None) -> float:
        dt = wake_at - time.monotonic()
        if dt > 0:
            if interrupt is not None:
                interrupt.wait(dt)
            else:
                time.sleep(dt)
        return time.monotonic()


class VirtualClock(Clock):
    """Deterministic discrete-event clock with a run-one-at-a-time turn
    discipline (see the module docstring for the full contract).

    Thread-safety: one condition variable guards all state.  A
    participant that exits must :meth:`unregister` (the runner does this
    in a ``finally``) or its peers would wait forever for its turn.
    """

    name = "virtual"
    deterministic = True

    def __init__(self, start: float = 0.0):
        self._cond = threading.Condition()
        self._now = float(start)
        self._tickets = itertools.count()
        self._registered: set = set()
        self._waiting: Dict[int, float] = {}   # ticket -> wake time
        self._running: Optional[int] = None

    def now(self) -> float:
        with self._cond:
            return self._now

    def register(self) -> int:
        with self._cond:
            t = next(self._tickets)
            self._registered.add(t)
            return t

    def unregister(self, ticket: int) -> None:
        with self._cond:
            self._registered.discard(ticket)
            self._waiting.pop(ticket, None)
            if self._running == ticket:
                self._running = None
            self._dispatch_locked()
            self._cond.notify_all()

    # ------------------------------------------------------------------
    def _dispatch_locked(self) -> None:
        """Advance time and hand the turn to the earliest waiter — only
        once every registered participant is parked (so no one is still
        running code whose shared-state effects could race the pick)."""
        if self._running is not None or not self._registered:
            return
        if any(t not in self._waiting for t in self._registered):
            return
        ticket = min(self._registered,
                     key=lambda t: (self._waiting[t], t))
        self._now = max(self._now, self._waiting.pop(ticket))
        self._running = ticket
        self._cond.notify_all()

    def sleep_until(self, ticket: int, wake_at: float,
                    interrupt: Optional[threading.Event] = None) -> float:
        with self._cond:
            if ticket not in self._registered:
                raise RuntimeError(
                    f"ticket {ticket} is not registered with this clock")
            self._waiting[ticket] = float(wake_at)
            if self._running == ticket:
                self._running = None
            self._dispatch_locked()
            while self._running != ticket:
                if interrupt is not None and interrupt.is_set():
                    # cancellation: give up the turn without deadlocking
                    # peers (determinism is over once a run is cancelled)
                    self._waiting.pop(ticket, None)
                    self._dispatch_locked()
                    self._cond.notify_all()
                    return self._now
                # timed wait so a set-after-check interrupt is still seen
                self._cond.wait(timeout=0.1)
            return self._now
