"""Skewed / shifting request samplers for multi-tenant workloads.

The default per-job request stream is the :class:`~repro.core.ods.
EpochSampler`'s uniform pseudo-random epoch permutation — every sample
exactly once per epoch, the paper's training workload.  Production
multi-tenant traffic is rarely that polite: serving-style jobs hammer a
Zipfian head, and training-over-changing-data walks a working set that
drifts.  This module provides drop-in request samplers for those shapes
(the ROADMAP's "skewed, shifting multi-tenant workloads" open item),
selected per job via ``JobSpec.sampler`` /
``SenecaServer.open_session(sampler=...)``.

All samplers implement the EpochSampler surface the service layer
consumes: ``next_request()`` (one batch of *distinct* ids), ``n`` /
``bs`` attributes, and ``state_dict()`` / ``load_state_dict()`` for the
fault-tolerance checkpoint path.  Unlike the epoch permutation they do
NOT promise once-per-epoch coverage — the ODS layer's substitution and
seen-tracking still apply downstream, so delivered batches keep the
ODS guarantees; only the *request* distribution changes.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.ods import EpochSampler

__all__ = ["ZipfianSampler", "PhaseShiftSampler", "make_request_sampler",
           "REQUEST_SAMPLERS"]


class ZipfianSampler:
    """Zipf(``alpha``)-weighted requests over a seed-shuffled rank
    assignment: rank-r ids are requested proportionally to
    ``(r+1)**-alpha``, so a small hot head dominates while the tail
    still appears.  Each batch draws ``bs`` *distinct* ids (weighted,
    without replacement) — the service layer assumes no duplicate ids
    within one request batch.

    Two jobs given the same seed share the same hot head (maximal
    working-set overlap, the coalescing benchmark's setup); different
    seeds give disjointly-shuffled heads.
    """

    name = "zipfian"

    def __init__(self, n_samples: int, batch_size: int, seed: int,
                 alpha: float = 1.1):
        if batch_size > n_samples:
            raise ValueError(f"batch_size {batch_size} > dataset size "
                             f"{n_samples}")
        self.n = n_samples
        self.bs = batch_size
        self.alpha = float(alpha)
        self.rng = np.random.default_rng(seed)
        # which ids are hot: a one-time seed-determined shuffle of the
        # rank order (id ranks[0] is the hottest)
        self._ranks = self.rng.permutation(self.n)
        w = (np.arange(self.n, dtype=np.float64) + 1.0) ** -self.alpha
        p = np.empty(self.n, np.float64)
        p[self._ranks] = w / w.sum()
        self._p = p

    def next_request(self) -> np.ndarray:
        return self.rng.choice(self.n, size=self.bs, replace=False,
                               p=self._p)

    # -- checkpoint surface (fault-tolerance path) ---------------------
    def state_dict(self) -> Dict:
        return {
            "kind": self.name,
            "n": self.n,
            "bs": self.bs,
            "alpha": self.alpha,
            "ranks": self._ranks.copy(),
            "rng_state": self.rng.bit_generator.state,
        }

    def load_state_dict(self, state: Dict) -> None:
        if int(state["n"]) != self.n or int(state["bs"]) != self.bs:
            raise ValueError(
                f"sampler snapshot is for n={state['n']} bs={state['bs']}"
                f", this sampler has n={self.n} bs={self.bs}")
        self._ranks = np.asarray(state["ranks"],
                                 dtype=self._ranks.dtype).copy()
        w = (np.arange(self.n, dtype=np.float64) + 1.0) ** -self.alpha
        p = np.empty(self.n, np.float64)
        p[self._ranks] = w / w.sum()
        self._p = p
        self.rng.bit_generator.state = state["rng_state"]


class PhaseShiftSampler:
    """A sliding working set: requests are drawn uniformly (distinct,
    without replacement) from a contiguous window of ``window`` ids,
    and every ``period`` batches the window slides forward by
    ``shift`` ids (wrapping at the dataset end) — a *phase shift*.

    Within one phase the traffic is an ideal cache workload (a small
    stable set); each shift invalidates ``shift`` ids' worth of cached
    work and warms new ones, exercising eviction/admission churn the
    uniform epoch permutation never produces.
    """

    name = "phase-shift"

    def __init__(self, n_samples: int, batch_size: int, seed: int,
                 window_frac: float = 0.25, period: int = 32,
                 shift_frac: float = 0.125):
        self.n = n_samples
        self.bs = batch_size
        self.window = max(batch_size, int(n_samples * window_frac))
        if self.window > n_samples:
            raise ValueError(f"batch_size {batch_size} > dataset size "
                             f"{n_samples}")
        self.period = max(1, int(period))
        self.shift = max(1, int(self.window * shift_frac))
        self.rng = np.random.default_rng(seed)
        self._offset = 0
        self._batches = 0

    def next_request(self) -> np.ndarray:
        if self._batches and self._batches % self.period == 0:
            self._offset = (self._offset + self.shift) % self.n
        self._batches += 1
        picks = self.rng.choice(self.window, size=self.bs, replace=False)
        return (self._offset + picks) % self.n

    # -- checkpoint surface (fault-tolerance path) ---------------------
    def state_dict(self) -> Dict:
        return {
            "kind": self.name,
            "n": self.n,
            "bs": self.bs,
            "window": self.window,
            "period": self.period,
            "shift": self.shift,
            "offset": int(self._offset),
            "batches": int(self._batches),
            "rng_state": self.rng.bit_generator.state,
        }

    def load_state_dict(self, state: Dict) -> None:
        if int(state["n"]) != self.n or int(state["bs"]) != self.bs:
            raise ValueError(
                f"sampler snapshot is for n={state['n']} bs={state['bs']}"
                f", this sampler has n={self.n} bs={self.bs}")
        self.window = int(state["window"])
        self.period = int(state["period"])
        self.shift = int(state["shift"])
        self._offset = int(state["offset"])
        self._batches = int(state["batches"])
        self.rng.bit_generator.state = state["rng_state"]


#: name -> factory(n_samples, batch_size, seed) registry ("epoch" is the
#: historical uniform permutation, the default everywhere)
REQUEST_SAMPLERS = {
    "epoch": EpochSampler,
    "zipfian": ZipfianSampler,
    "phase-shift": PhaseShiftSampler,
}


def make_request_sampler(spec: Optional[str], n_samples: int,
                         batch_size: int, seed: int):
    """Resolve a request sampler: None / "epoch" -> the historical
    :class:`EpochSampler` (byte-identical default), a registered name
    -> that sampler, a callable -> ``spec(n_samples, batch_size,
    seed)`` (escape hatch for parameterized instances)."""
    if spec is None:
        return EpochSampler(n_samples, batch_size, seed)
    if callable(spec):
        return spec(n_samples, batch_size, seed)
    try:
        factory = REQUEST_SAMPLERS[spec]
    except KeyError:
        raise ValueError(
            f"unknown request sampler {spec!r}; registered: "
            f"{tuple(sorted(REQUEST_SAMPLERS))}") from None
    return factory(n_samples, batch_size, seed)
