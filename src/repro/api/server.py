"""SenecaServer + Session: the public face of the cache/sampler service.

The seed exposed the paper's Figure-7 loop as :class:`SenecaService` with
raw ``job_id`` ints threaded through every call and pipelines poking
``svc.cache.parts[...]`` for admission.  This module keeps that engine
(same name, now policy-driven) and wraps it in a session facade::

    server = SenecaServer.for_dataset(ds, cache_frac=0.35)
    with server.open_session(batch_size=32) as sess:
        ids, forms = sess.next_batch_ids()
        ...
    print(server.stats())

Sessions own job registration/unregistration — opening one bumps the ODS
job count (and with it the refcount-eviction threshold), closing it drops
both — so the paper's headline many-jobs-one-cache scenario is just N
``open_session`` calls against one server.

Construction knobs (``SenecaConfig`` fields or ``SenecaServer`` kwargs):
``backend`` selects the ODS metadata engine ("numpy" | "jax" — the latter
runs the fused ``ods_jax.substitute_jit`` kernel), and ``sampler`` /
``admission`` / ``eviction`` select policies by registered name
(see :mod:`repro.api.policies`).
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

import numpy as np

from repro.api.backends import NO_REFCOUNT_EVICT, resolve_backend
from repro.api.policies import resolve_policy
from repro.cache.store import FORMS, TieredCache
from repro.core import mdp
from repro.core.ods import (AUGMENTED, DECODED, ENCODED, IN_STORAGE,
                            EpochSampler)
from repro.core.perf_model import (AZURE_NC96, DatasetProfile,
                                   HardwareProfile, JobProfile)

__all__ = ["SenecaConfig", "SenecaService", "SenecaServer", "Session",
           "SessionClosed", "FORM_CODE", "CODE_FORM"]

FORM_CODE = {"encoded": ENCODED, "decoded": DECODED, "augmented": AUGMENTED}
CODE_FORM = {v: k for k, v in FORM_CODE.items()}


class SessionClosed(RuntimeError):
    """Raised when a closed Session is asked to sample."""


@dataclass
class SenecaConfig:
    cache_bytes: int
    hardware: HardwareProfile
    dataset: DatasetProfile
    job: JobProfile = field(default_factory=JobProfile)
    partition_step: float = 0.01
    seed: int = 0
    use_ods: bool = True          # False -> MDP-only (paper's "MDP" bar)
    # manual override (x_e, x_d, x_a); None -> run MDP
    split: Optional[Tuple[float, float, float]] = None
    # facade knobs: ODS metadata engine + policies by registered name
    backend: str = "numpy"
    sampler: Optional[str] = None      # None -> "ods" / "naive" per use_ods
    admission: Optional[str] = None    # None -> "unseen-only" / "capacity"
    eviction: Optional[str] = None     # None -> "refcount"


class SenecaService:
    """One shared dataset's cache + sampler engine (policy-driven).

    Prefer :class:`SenecaServer` / :class:`Session`; this class remains the
    synchronous engine underneath and the back-compat surface for the old
    ``register_job``/``job_id`` call style.
    """

    def __init__(self, cfg: SenecaConfig, *, backend=None, sampler=None,
                 admission=None, eviction=None):
        self.cfg = cfg
        if cfg.split is not None:
            self.partition = mdp.Partition(*cfg.split, throughput=float("nan"))
        else:
            hw = cfg.hardware
            if hw.s_cache != cfg.cache_bytes:
                hw = replace(hw, s_cache=float(cfg.cache_bytes))
            self.partition = mdp.optimize(hw, cfg.dataset, cfg.job,
                                          cfg.partition_step)
        self.sampler = resolve_policy(
            "sampler", sampler or cfg.sampler
            or ("ods" if cfg.use_ods else "naive"))
        self.admission = resolve_policy(
            "admission", admission or cfg.admission
            or ("unseen-only" if cfg.use_ods else "capacity"))
        self.eviction = resolve_policy(
            "eviction", eviction or cfg.eviction or "refcount")
        self.cache = TieredCache(
            cfg.cache_bytes,
            (self.partition.x_e, self.partition.x_d, self.partition.x_a),
            evict_policies=self.eviction.partition_policies())
        self.backend = resolve_backend(backend or cfg.backend,
                                       cfg.dataset.n_total, seed=cfg.seed)
        self.rng = np.random.default_rng(cfg.seed + 1)
        self._samplers: Dict[int, EpochSampler] = {}
        self._lock = threading.Lock()
        self._refill_pending: list = []

    # legacy alias: the engine's ODS metadata (numpy state or jax adapter)
    @property
    def ods(self):
        return getattr(self.backend, "state", self.backend)

    # ------------------------------------------------------------------
    def register_job(self, job_id: int, batch_size: int) -> None:
        with self._lock:
            self.backend.register_job(job_id)
            self._samplers[job_id] = EpochSampler(
                self.cfg.dataset.n_total, batch_size,
                self.cfg.seed + 97 * (job_id + 1))

    def unregister_job(self, job_id: int) -> None:
        with self._lock:
            self.backend.unregister_job(job_id)
            self._samplers.pop(job_id, None)

    # ------------------------------------------------------------------
    def next_batch_ids(self, job_id: int) -> Tuple[np.ndarray, np.ndarray]:
        """Sample a batch for ``job_id``.

        Returns (ids, forms): forms is the uint8 status of each id, i.e.
        which tier will serve it (0 = storage fetch).
        """
        with self._lock:
            requested = self._samplers[job_id].next_request()
            thr = self.eviction.threshold(self.backend)
            batch, evicted = self.sampler.sample(
                self.backend, job_id, requested,
                NO_REFCOUNT_EVICT if thr is None else thr)
            if len(evicted):
                for k in evicted:
                    self.cache.evict(int(k), "augmented")
                self._refill_pending.extend(int(k) for k in evicted)
            forms = self.backend.status_of(batch)
            return batch, forms

    # ------------------------------------------------------------------
    def admit(self, sample_id: int, form: str, value, nbytes: int) -> bool:
        """Policy-gated insert; updates ODS status on success.

        The metadata vote (``AdmissionPolicy.wants``) runs under the
        service lock, the capacity vote + insert run atomically under the
        cache lock (no check-then-act window between them).
        """
        # partition capacities are immutable after construction: skip the
        # locks entirely for tiers the MDP split zeroed out (pipeline
        # workers admit every produced form on the hot path)
        if self.cache.parts[form].capacity == 0:
            return False
        with self._lock:
            if not self.admission.wants(self.backend, sample_id, form):
                return False
        ok = self.cache.insert_gated(sample_id, form, value, nbytes,
                                     self.admission)
        if ok:
            with self._lock:
                self.backend.mark_cached(np.asarray([sample_id]),
                                         FORM_CODE[form])
        return ok

    def refill_candidates(self, k: int) -> np.ndarray:
        """Background-refill picks: random storage-resident samples
        (paper step 5: evicted slots repopulate pseudo-randomly)."""
        with self._lock:
            pool = self.backend.storage_pool()
            if not len(pool):
                return pool
            return self.rng.choice(pool, size=min(k, len(pool)),
                                   replace=False)

    def take_refill_work(self, max_n: int = 64) -> np.ndarray:
        """Claim pending eviction slots and return fresh random samples to
        preprocess into them (the paper's background-refill thread body)."""
        with self._lock:
            n = min(len(self._refill_pending), max_n)
            if not n:
                return np.empty(0, np.int64)
            del self._refill_pending[:n]
        return self.refill_candidates(n)

    def lookup(self, sample_id: int):
        return self.cache.lookup(sample_id)

    def tier_capacity(self, form: str) -> int:
        return self.cache.parts[form].capacity

    def tier_free_bytes(self, form: str) -> int:
        with self.cache.lock:
            return self.cache.parts[form].free_bytes

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        tiers = np.bincount(
            self.cache.status_array(self.cfg.dataset.n_total), minlength=4)
        return {
            "partition": self.partition.label,
            "predicted_throughput": self.partition.throughput,
            "backend": self.backend.name,
            "policies": {"sampler": self.sampler.name,
                         "admission": self.admission.name,
                         "eviction": self.eviction.name},
            "ods_hit_rate": self.backend.hit_rate(),
            "hits": self.backend.hits,
            "misses": self.backend.misses,
            "substitutions": self.backend.substitutions,
            "cache_bytes_used": self.cache.bytes_used(),
            "cache_lookup_hit_rate": self.cache.hit_rate(),
            "tier_counts": {form: int(tiers[FORM_CODE[form]])
                            for form in FORMS},
            "metadata_bytes": self.backend.metadata_bytes(),
        }


class Session:
    """One training job's handle on a shared SenecaServer.

    Owns the job registration: constructing (via ``open_session``) bumps
    the server's ODS job count, ``close()`` (or leaving the ``with`` block)
    drops it — which also lowers the refcount-eviction threshold for the
    remaining sessions.
    """

    def __init__(self, service: SenecaService, job_id: int,
                 batch_size: int, on_close=None):
        self.service = service
        self.job_id = job_id
        self.batch_size = batch_size
        self._on_close = on_close
        self._closed = False

    # ------------------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def epoch(self) -> int:
        return self.service.backend.epoch_of(self.job_id)

    def next_batch_ids(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._closed:
            raise SessionClosed(
                f"session {self.job_id} is closed; open a new one with "
                f"SenecaServer.open_session()")
        return self.service.next_batch_ids(self.job_id)

    def admit(self, sample_id: int, form: str, value, nbytes: int) -> bool:
        # in-flight pipeline workers may race a close(); drop their
        # admissions instead of corrupting the unregistered job's metadata
        if self._closed:
            return False
        return self.service.admit(sample_id, form, value, nbytes)

    def lookup(self, sample_id: int):
        return self.service.lookup(sample_id)

    def stats(self) -> Dict[str, float]:
        out = self.service.stats()
        out["session"] = {"job_id": self.job_id, "epoch": self.epoch,
                          "batch_size": self.batch_size,
                          "closed": self._closed}
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.service.unregister_job(self.job_id)
        if self._on_close is not None:
            self._on_close(self)

    # ------------------------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SenecaServer:
    """Facade handing out Sessions over one shared cache+sampler service."""

    def __init__(self, cfg: SenecaConfig = None, *, backend=None,
                 sampler=None, admission=None, eviction=None,
                 service: Optional[SenecaService] = None):
        if service is None:
            if cfg is None:
                raise ValueError("SenecaServer needs a SenecaConfig "
                                 "(or an existing service=)")
            service = SenecaService(cfg, backend=backend, sampler=sampler,
                                    admission=admission, eviction=eviction)
        self.service = service
        self._ids = itertools.count()
        self._sessions: Dict[int, Session] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    @classmethod
    def for_dataset(cls, ds, cache_bytes: Optional[int] = None,
                    cache_frac: float = 0.4,
                    hardware: HardwareProfile = AZURE_NC96,
                    **cfg_kwargs) -> "SenecaServer":
        """Build a server for a :mod:`repro.data.synthetic`-style dataset
        (anything with n_samples / mean_encoded_bytes / decoded_bytes() /
        augmented_bytes()), sizing the cache as a fraction of the
        fully-augmented dataset unless ``cache_bytes`` is given."""
        profile = DatasetProfile(ds.name, ds.n_samples,
                                 ds.mean_encoded_bytes,
                                 decoded_bytes=ds.decoded_bytes(),
                                 augmented_bytes=ds.augmented_bytes())
        if cache_bytes is None:
            cache_bytes = int(cache_frac * ds.n_samples
                              * ds.augmented_bytes())
        return cls(SenecaConfig(cache_bytes=cache_bytes, hardware=hardware,
                                dataset=profile, **cfg_kwargs))

    # ------------------------------------------------------------------
    def open_session(self, batch_size: int) -> Session:
        with self._lock:
            job_id = next(self._ids)
            self.service.register_job(job_id, batch_size)
            sess = Session(self.service, job_id, batch_size,
                           on_close=self._forget)
            self._sessions[job_id] = sess
            return sess

    def _forget(self, sess: Session) -> None:
        with self._lock:
            self._sessions.pop(sess.job_id, None)

    @property
    def n_sessions(self) -> int:
        with self._lock:
            return len(self._sessions)

    @property
    def partition(self):
        return self.service.partition

    def stats(self) -> Dict[str, float]:
        out = self.service.stats()
        out["n_sessions"] = self.n_sessions
        return out

    def close(self) -> None:
        with self._lock:
            live = list(self._sessions.values())
        for sess in live:
            sess.close()

    # ------------------------------------------------------------------
    def __enter__(self) -> "SenecaServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
